//! Dense row-major f32 matrix.
//!
//! The shared currency between the workload generators, the golden f32
//! trainer, the MX quantizers, and the hardware simulators. Deliberately
//! minimal — just what GeMM-shaped training needs.

#![forbid(unsafe_code)]

use crate::util::rng::Pcg64;

/// Output-row band size for the parallel GeMM kernels: fork over
/// ~4 bands per worker when the product is big enough to amortize the
/// fork-join (`total_work` = m*k*n flops), else one band (the chunk
/// helper then runs serially). Shared by `matmul`/`matmul_nt`/
/// `matmul_tn` so the three kernels always make the same fork decision.
fn par_band_rows(rows: usize, total_work: usize) -> usize {
    let nthreads = crate::util::par::threads();
    if nthreads > 1 && rows >= 2 && total_work >= 1 << 20 {
        rows.div_ceil(nthreads * 4).max(1)
    } else {
        rows.max(1)
    }
}

/// Dense row-major `rows x cols` f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix from a row-major vec (length must equal rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Matrix with entries from `f(r, c)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Self::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.data[r * cols + c] = f(r, c);
            }
        }
        m
    }

    /// Gaussian random matrix, N(0, sigma).
    pub fn randn(rows: usize, cols: usize, sigma: f32, rng: &mut Pcg64) -> Self {
        let mut m = Self::zeros(rows, cols);
        rng.fill_normal(&mut m.data, sigma);
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// `self @ other` — naive triple loop with a k-blocked inner order
    /// (row-major friendly). Good enough as the golden reference; the
    /// cycle-accurate path is the simulator, not this.
    ///
    /// §Parallel: output rows are independent and each row runs the exact
    /// serial k-loop, so large GeMMs fork over row bands bit-identically
    /// to the serial loop (asserted by `tests/parallel.rs`). Small
    /// products stay serial — the fork-join would dominate.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "inner dims mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        let (cols, ocols) = (self.cols, other.cols);
        let band = par_band_rows(self.rows, self.rows * cols * ocols);
        crate::util::par::par_chunks_mut(&mut out.data, band * ocols, 2, |ci, chunk| {
            let r0 = ci * band;
            for (dr, dst) in chunk.chunks_mut(ocols).enumerate() {
                let r = r0 + dr;
                for k in 0..cols {
                    let a = self.data[r * cols + k];
                    if a == 0.0 {
                        continue;
                    }
                    let orow = &other.data[k * ocols..(k + 1) * ocols];
                    for (d, &b) in dst.iter_mut().zip(orow) {
                        *d += a * b;
                    }
                }
            }
        });
        out
    }

    /// `self @ otherᵀ` without materializing the transpose.
    ///
    /// Bit-identical to `self.matmul(&other.transpose())`: every output
    /// element accumulates the same products in the same k order, with
    /// the same zero-skip on the left operand, and the parallel banding
    /// splits output rows exactly like [`Mat::matmul`]. Used by the
    /// error-backprop GeMM (`E @ Wᵀ`) so backends never allocate a
    /// transposed weight copy — the software mirror of the paper's
    /// free square-block transpose.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "inner dims mismatch");
        let mut out = Mat::zeros(self.rows, other.rows);
        let (k_len, ocols) = (self.cols, other.rows);
        let band = par_band_rows(self.rows, self.rows * k_len * ocols);
        crate::util::par::par_chunks_mut(&mut out.data, band * ocols, 2, |ci, chunk| {
            let r0 = ci * band;
            for (dr, dst) in chunk.chunks_mut(ocols).enumerate() {
                let arow = &self.data[(r0 + dr) * k_len..(r0 + dr + 1) * k_len];
                for (j, d) in dst.iter_mut().enumerate() {
                    let brow = &other.data[j * k_len..(j + 1) * k_len];
                    let mut s = 0.0f32;
                    for (k, &a) in arow.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        s += a * brow[k];
                    }
                    *d = s;
                }
            }
        });
        out
    }

    /// `selfᵀ @ other` without materializing the transpose.
    ///
    /// Bit-identical to `self.transpose().matmul(other)` (same per-
    /// element accumulation order and zero-skip). Used by the weight-
    /// gradient GeMM (`Aᵀ @ E`) over the stored quantized activations.
    pub fn matmul_tn(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "inner dims mismatch");
        let mut out = Mat::zeros(self.cols, other.cols);
        let (k_len, ocols) = (self.rows, other.cols);
        let orows = self.cols;
        let band = par_band_rows(orows, orows * k_len * ocols);
        crate::util::par::par_chunks_mut(&mut out.data, band * ocols, 2, |ci, chunk| {
            let r0 = ci * band;
            for (dr, dst) in chunk.chunks_mut(ocols).enumerate() {
                let i = r0 + dr; // output row i = column i of self
                for k in 0..k_len {
                    let a = self.data[k * self.cols + i];
                    if a == 0.0 {
                        continue;
                    }
                    let orow = &other.data[k * ocols..(k + 1) * ocols];
                    for (d, &b) in dst.iter_mut().zip(orow) {
                        *d += a * b;
                    }
                }
            }
        });
        out
    }

    /// `self @ other` with **block-ordered accumulation**: the k
    /// dimension is consumed in `chunk`-element segments, each segment's
    /// partial dot is accumulated as an f64 chain (products of two f32s
    /// are exact in f64), rounded to f32 once, and the f32 partials are
    /// then chained across segments.
    ///
    /// This is the value semantics of the MX square-block datapath —
    /// "apply the per-block scale once per block" — expressed on dense
    /// operands. When both operands are square-block fake-quantized MX
    /// tensors and `chunk` equals the block edge (8), every segment
    /// partial is *exact* (the segment's products are integer multiples
    /// of one power-of-two unit with < 2^53 dynamic range), which is
    /// what makes the bit-packed integer SWAR kernels in `mx::packed`
    /// bit-identical to this kernel — a theorem, not a tolerance
    /// (asserted across backends by `tests/backend.rs`).
    ///
    /// Parallel over output-row bands exactly like [`Mat::matmul`];
    /// banding never changes a bit because each output element's
    /// accumulation chain is fully determined by (row, col).
    pub fn matmul_blocked(&self, other: &Mat, chunk: usize) -> Mat {
        assert_eq!(self.cols, other.rows, "inner dims mismatch");
        let chunk = chunk.max(1);
        let mut out = Mat::zeros(self.rows, other.cols);
        let (k_len, ocols) = (self.cols, other.cols);
        let band = par_band_rows(self.rows, self.rows * k_len * ocols);
        crate::util::par::par_chunks_mut(&mut out.data, band * ocols, 2, |ci, rows| {
            let r0 = ci * band;
            let mut acc = vec![0.0f64; ocols];
            for (dr, dst) in rows.chunks_mut(ocols).enumerate() {
                let r = r0 + dr;
                let mut k0 = 0;
                while k0 < k_len {
                    let kend = (k0 + chunk).min(k_len);
                    acc.fill(0.0);
                    for k in k0..kend {
                        let a = self.data[r * k_len + k];
                        if a == 0.0 {
                            continue;
                        }
                        let a = a as f64;
                        let orow = &other.data[k * ocols..(k + 1) * ocols];
                        for (d, &b) in acc.iter_mut().zip(orow) {
                            *d += a * b as f64;
                        }
                    }
                    for (d, &p) in dst.iter_mut().zip(acc.iter()) {
                        *d += p as f32;
                    }
                    k0 = kend;
                }
            }
        });
        out
    }

    /// `self @ otherᵀ` with block-ordered accumulation (see
    /// [`Mat::matmul_blocked`]); the transpose is never materialized.
    pub fn matmul_blocked_nt(&self, other: &Mat, chunk: usize) -> Mat {
        assert_eq!(self.cols, other.cols, "inner dims mismatch");
        let chunk = chunk.max(1);
        let mut out = Mat::zeros(self.rows, other.rows);
        let (k_len, ocols) = (self.cols, other.rows);
        let band = par_band_rows(self.rows, self.rows * k_len * ocols);
        crate::util::par::par_chunks_mut(&mut out.data, band * ocols, 2, |ci, rows| {
            let r0 = ci * band;
            for (dr, dst) in rows.chunks_mut(ocols).enumerate() {
                let arow = &self.data[(r0 + dr) * k_len..(r0 + dr + 1) * k_len];
                for (j, d) in dst.iter_mut().enumerate() {
                    let brow = &other.data[j * k_len..(j + 1) * k_len];
                    let mut s = 0.0f32;
                    let mut k0 = 0;
                    while k0 < k_len {
                        let kend = (k0 + chunk).min(k_len);
                        let mut p = 0.0f64;
                        for k in k0..kend {
                            let a = arow[k];
                            if a == 0.0 {
                                continue;
                            }
                            p += a as f64 * brow[k] as f64;
                        }
                        s += p as f32;
                        k0 = kend;
                    }
                    *d = s;
                }
            }
        });
        out
    }

    /// `selfᵀ @ other` with block-ordered accumulation (see
    /// [`Mat::matmul_blocked`]); the transpose is never materialized.
    pub fn matmul_blocked_tn(&self, other: &Mat, chunk: usize) -> Mat {
        assert_eq!(self.rows, other.rows, "inner dims mismatch");
        let chunk = chunk.max(1);
        let mut out = Mat::zeros(self.cols, other.cols);
        let (k_len, ocols) = (self.rows, other.cols);
        let orows = self.cols;
        let band = par_band_rows(orows, orows * k_len * ocols);
        crate::util::par::par_chunks_mut(&mut out.data, band * ocols, 2, |ci, rows| {
            let r0 = ci * band;
            let mut acc = vec![0.0f64; ocols];
            for (dr, dst) in rows.chunks_mut(ocols).enumerate() {
                let i = r0 + dr; // output row i = column i of self
                let mut k0 = 0;
                while k0 < k_len {
                    let kend = (k0 + chunk).min(k_len);
                    acc.fill(0.0);
                    for k in k0..kend {
                        let a = self.data[k * self.cols + i];
                        if a == 0.0 {
                            continue;
                        }
                        let a = a as f64;
                        let orow = &other.data[k * ocols..(k + 1) * ocols];
                        for (d, &b) in acc.iter_mut().zip(orow) {
                            *d += a * b as f64;
                        }
                    }
                    for (d, &p) in dst.iter_mut().zip(acc.iter()) {
                        *d += p as f32;
                    }
                    k0 = kend;
                }
            }
        });
        out
    }

    /// Serial twin of [`Mat::matmul`]: same setup, same per-band loop
    /// body, run through [`crate::util::par::par_chunks_mut_serial`] —
    /// bit-identical by construction (`tests/parallel.rs`).
    pub fn matmul_serial(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "inner dims mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        let (cols, ocols) = (self.cols, other.cols);
        let band = par_band_rows(self.rows, self.rows * cols * ocols);
        crate::util::par::par_chunks_mut_serial(&mut out.data, band * ocols, |ci, chunk| {
            let r0 = ci * band;
            for (dr, dst) in chunk.chunks_mut(ocols).enumerate() {
                let r = r0 + dr;
                for k in 0..cols {
                    let a = self.data[r * cols + k];
                    if a == 0.0 {
                        continue;
                    }
                    let orow = &other.data[k * ocols..(k + 1) * ocols];
                    for (d, &b) in dst.iter_mut().zip(orow) {
                        *d += a * b;
                    }
                }
            }
        });
        out
    }

    /// Serial twin of [`Mat::matmul_nt`] (see [`Mat::matmul_serial`]).
    pub fn matmul_nt_serial(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "inner dims mismatch");
        let mut out = Mat::zeros(self.rows, other.rows);
        let (k_len, ocols) = (self.cols, other.rows);
        let band = par_band_rows(self.rows, self.rows * k_len * ocols);
        crate::util::par::par_chunks_mut_serial(&mut out.data, band * ocols, |ci, chunk| {
            let r0 = ci * band;
            for (dr, dst) in chunk.chunks_mut(ocols).enumerate() {
                let arow = &self.data[(r0 + dr) * k_len..(r0 + dr + 1) * k_len];
                for (j, d) in dst.iter_mut().enumerate() {
                    let brow = &other.data[j * k_len..(j + 1) * k_len];
                    let mut s = 0.0f32;
                    for (k, &a) in arow.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        s += a * brow[k];
                    }
                    *d = s;
                }
            }
        });
        out
    }

    /// Serial twin of [`Mat::matmul_tn`] (see [`Mat::matmul_serial`]).
    pub fn matmul_tn_serial(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "inner dims mismatch");
        let mut out = Mat::zeros(self.cols, other.cols);
        let (k_len, ocols) = (self.rows, other.cols);
        let orows = self.cols;
        let band = par_band_rows(orows, orows * k_len * ocols);
        crate::util::par::par_chunks_mut_serial(&mut out.data, band * ocols, |ci, chunk| {
            let r0 = ci * band;
            for (dr, dst) in chunk.chunks_mut(ocols).enumerate() {
                let i = r0 + dr; // output row i = column i of self
                for k in 0..k_len {
                    let a = self.data[k * self.cols + i];
                    if a == 0.0 {
                        continue;
                    }
                    let orow = &other.data[k * ocols..(k + 1) * ocols];
                    for (d, &b) in dst.iter_mut().zip(orow) {
                        *d += a * b;
                    }
                }
            }
        });
        out
    }

    /// Serial twin of [`Mat::matmul_blocked`] (see
    /// [`Mat::matmul_serial`]).
    pub fn matmul_blocked_serial(&self, other: &Mat, chunk: usize) -> Mat {
        assert_eq!(self.cols, other.rows, "inner dims mismatch");
        let chunk = chunk.max(1);
        let mut out = Mat::zeros(self.rows, other.cols);
        let (k_len, ocols) = (self.cols, other.cols);
        let band = par_band_rows(self.rows, self.rows * k_len * ocols);
        crate::util::par::par_chunks_mut_serial(&mut out.data, band * ocols, |ci, rows| {
            let r0 = ci * band;
            let mut acc = vec![0.0f64; ocols];
            for (dr, dst) in rows.chunks_mut(ocols).enumerate() {
                let r = r0 + dr;
                let mut k0 = 0;
                while k0 < k_len {
                    let kend = (k0 + chunk).min(k_len);
                    acc.fill(0.0);
                    for k in k0..kend {
                        let a = self.data[r * k_len + k];
                        if a == 0.0 {
                            continue;
                        }
                        let a = a as f64;
                        let orow = &other.data[k * ocols..(k + 1) * ocols];
                        for (d, &b) in acc.iter_mut().zip(orow) {
                            *d += a * b as f64;
                        }
                    }
                    for (d, &p) in dst.iter_mut().zip(acc.iter()) {
                        *d += p as f32;
                    }
                    k0 = kend;
                }
            }
        });
        out
    }

    /// Serial twin of [`Mat::matmul_blocked_nt`] (see
    /// [`Mat::matmul_serial`]).
    pub fn matmul_blocked_nt_serial(&self, other: &Mat, chunk: usize) -> Mat {
        assert_eq!(self.cols, other.cols, "inner dims mismatch");
        let chunk = chunk.max(1);
        let mut out = Mat::zeros(self.rows, other.rows);
        let (k_len, ocols) = (self.cols, other.rows);
        let band = par_band_rows(self.rows, self.rows * k_len * ocols);
        crate::util::par::par_chunks_mut_serial(&mut out.data, band * ocols, |ci, rows| {
            let r0 = ci * band;
            for (dr, dst) in rows.chunks_mut(ocols).enumerate() {
                let arow = &self.data[(r0 + dr) * k_len..(r0 + dr + 1) * k_len];
                for (j, d) in dst.iter_mut().enumerate() {
                    let brow = &other.data[j * k_len..(j + 1) * k_len];
                    let mut s = 0.0f32;
                    let mut k0 = 0;
                    while k0 < k_len {
                        let kend = (k0 + chunk).min(k_len);
                        let mut p = 0.0f64;
                        for k in k0..kend {
                            let a = arow[k];
                            if a == 0.0 {
                                continue;
                            }
                            p += a as f64 * brow[k] as f64;
                        }
                        s += p as f32;
                        k0 = kend;
                    }
                    *d = s;
                }
            }
        });
        out
    }

    /// Serial twin of [`Mat::matmul_blocked_tn`] (see
    /// [`Mat::matmul_serial`]).
    pub fn matmul_blocked_tn_serial(&self, other: &Mat, chunk: usize) -> Mat {
        assert_eq!(self.rows, other.rows, "inner dims mismatch");
        let chunk = chunk.max(1);
        let mut out = Mat::zeros(self.cols, other.cols);
        let (k_len, ocols) = (self.rows, other.cols);
        let orows = self.cols;
        let band = par_band_rows(orows, orows * k_len * ocols);
        crate::util::par::par_chunks_mut_serial(&mut out.data, band * ocols, |ci, rows| {
            let r0 = ci * band;
            let mut acc = vec![0.0f64; ocols];
            for (dr, dst) in rows.chunks_mut(ocols).enumerate() {
                let i = r0 + dr; // output row i = column i of self
                let mut k0 = 0;
                while k0 < k_len {
                    let kend = (k0 + chunk).min(k_len);
                    acc.fill(0.0);
                    for k in k0..kend {
                        let a = self.data[k * self.cols + i];
                        if a == 0.0 {
                            continue;
                        }
                        let a = a as f64;
                        let orow = &other.data[k * ocols..(k + 1) * ocols];
                        for (d, &b) in acc.iter_mut().zip(orow) {
                            *d += a * b as f64;
                        }
                    }
                    for (d, &p) in dst.iter_mut().zip(acc.iter()) {
                        *d += p as f32;
                    }
                    k0 = kend;
                }
            }
        });
        out
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// Elementwise binary zip.
    pub fn zip(&self, other: &Mat, f: impl Fn(f32, f32) -> f32) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (d, &s) in self.data.iter_mut().zip(&other.data) {
            *d += alpha * s;
        }
    }

    /// Add a row-vector bias to every row.
    pub fn add_bias(&self, bias: &[f32]) -> Mat {
        assert_eq!(bias.len(), self.cols);
        Mat::from_fn(self.rows, self.cols, |r, c| self.at(r, c) + bias[c])
    }

    /// In-place row-vector bias add (same values as [`Mat::add_bias`],
    /// no allocation — the QAT step's per-layer hot path).
    pub fn add_bias_in_place(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for row in self.data.chunks_mut(self.cols.max(1)) {
            for (d, &b) in row.iter_mut().zip(bias) {
                *d += b;
            }
        }
    }

    /// Overwrite `self` with a copy of `src`, reusing the existing
    /// allocation when its capacity suffices (backend scratch buffers).
    pub fn copy_from(&mut self, src: &Mat) {
        self.rows = src.rows;
        self.cols = src.cols;
        self.data.clear();
        self.data.extend_from_slice(&src.data);
    }

    /// Column sums (used for bias gradients).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut s = vec![0.0; self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                s[c] += self.at(r, c);
            }
        }
        s
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Mean squared difference against another matrix.
    pub fn mse(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            / n as f64
    }

    /// Extract an `h x w` sub-block starting at `(r0, c0)`, zero-padded
    /// past the matrix edge (hardware tiles always read full blocks).
    pub fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Mat {
        Mat::from_fn(h, w, |r, c| {
            let (rr, cc) = (r0 + r, c0 + c);
            if rr < self.rows && cc < self.cols {
                self.at(rr, cc)
            } else {
                0.0
            }
        })
    }

    /// Write `blk` into `self` at `(r0, c0)`, clipping at the edge.
    pub fn set_block(&mut self, r0: usize, c0: usize, blk: &Mat) {
        for r in 0..blk.rows {
            for c in 0..blk.cols {
                let (rr, cc) = (r0 + r, c0 + c);
                if rr < self.rows && cc < self.cols {
                    *self.at_mut(rr, cc) = blk.at(r, c);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::new(1);
        let a = Mat::randn(5, 7, 1.0, &mut rng);
        let i = Mat::from_fn(7, 7, |r, c| if r == c { 1.0 } else { 0.0 });
        assert_eq!(a.matmul(&i).data, a.data);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(2);
        let a = Mat::randn(3, 9, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn transpose_matmul_agrees() {
        // (A B)^T == B^T A^T
        let mut rng = Pcg64::new(3);
        let a = Mat::randn(4, 6, 1.0, &mut rng);
        let b = Mat::randn(6, 5, 1.0, &mut rng);
        let lhs = a.matmul(&b).transpose();
        let rhs = b.transpose().matmul(&a.transpose());
        assert!(lhs.mse(&rhs) < 1e-12);
    }

    #[test]
    fn matmul_nt_bit_identical_to_materialized_transpose() {
        let mut rng = Pcg64::new(7);
        for (m, k, n) in [(4, 6, 5), (1, 1, 1), (13, 21, 9), (32, 64, 32)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            // sprinkle zeros to exercise the skip path
            let a = a.map(|v| if v.abs() < 0.3 { 0.0 } else { v });
            let b = Mat::randn(n, k, 1.0, &mut rng);
            let fast = a.matmul_nt(&b);
            let slow = a.matmul(&b.transpose());
            assert_eq!(fast.data, slow.data, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_tn_bit_identical_to_materialized_transpose() {
        let mut rng = Pcg64::new(8);
        for (m, k, n) in [(4, 6, 5), (1, 1, 1), (21, 13, 9), (64, 32, 64)] {
            let a = Mat::randn(k, m, 1.0, &mut rng);
            let a = a.map(|v| if v.abs() < 0.3 { 0.0 } else { v });
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let fast = a.matmul_tn(&b);
            let slow = a.transpose().matmul(&b);
            assert_eq!(fast.data, slow.data, "{m}x{k}x{n}");
        }
    }

    /// Serial reference of the blocked semantics: per output element,
    /// k in `chunk`-segments, f64 chain within a segment (left-operand
    /// zero skip), f32 chain across segment partials.
    fn blocked_ref(a: &Mat, b: &Mat, chunk: usize) -> Mat {
        let mut out = Mat::zeros(a.rows, b.cols);
        for r in 0..a.rows {
            for c in 0..b.cols {
                let mut s = 0.0f32;
                let mut k0 = 0;
                while k0 < a.cols {
                    let kend = (k0 + chunk).min(a.cols);
                    let mut p = 0.0f64;
                    for k in k0..kend {
                        let av = a.at(r, k);
                        if av == 0.0 {
                            continue;
                        }
                        p += av as f64 * b.at(k, c) as f64;
                    }
                    s += p as f32;
                    k0 = kend;
                }
                *out.at_mut(r, c) = s;
            }
        }
        out
    }

    #[test]
    fn matmul_blocked_matches_serial_reference() {
        let mut rng = Pcg64::new(21);
        for (m, k, n) in [(1, 1, 1), (13, 21, 9), (16, 24, 8), (33, 40, 17)] {
            let a = Mat::randn(m, k, 1.0, &mut rng).map(|v| if v.abs() < 0.3 { 0.0 } else { v });
            let b = Mat::randn(k, n, 1.0, &mut rng);
            for chunk in [1usize, 8, 1000] {
                let fast = a.matmul_blocked(&b, chunk);
                let slow = blocked_ref(&a, &b, chunk);
                let bits = |m: &Mat| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&fast), bits(&slow), "{m}x{k}x{n} chunk {chunk}");
            }
        }
    }

    #[test]
    fn matmul_blocked_nt_tn_match_materialized_transposes() {
        let mut rng = Pcg64::new(22);
        for (m, k, n) in [(4, 6, 5), (13, 21, 9), (32, 64, 32)] {
            let a = Mat::randn(m, k, 1.0, &mut rng).map(|v| if v.abs() < 0.3 { 0.0 } else { v });
            let bt = Mat::randn(n, k, 1.0, &mut rng);
            let bits = |m: &Mat| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(
                bits(&a.matmul_blocked_nt(&bt, 8)),
                bits(&a.matmul_blocked(&bt.transpose(), 8)),
                "nt {m}x{k}x{n}"
            );
            let at = a.transpose(); // k x m
            let b = Mat::randn(k, n, 1.0, &mut rng);
            assert_eq!(
                bits(&at.matmul_blocked_tn(&b, 8)),
                bits(&a.matmul_blocked(&b, 8)),
                "tn {m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn add_bias_in_place_matches_add_bias() {
        let mut rng = Pcg64::new(9);
        let a = Mat::randn(5, 7, 1.0, &mut rng);
        let bias: Vec<f32> = (0..7).map(|i| i as f32 * 0.25 - 1.0).collect();
        let want = a.add_bias(&bias);
        let mut got = a.clone();
        got.add_bias_in_place(&bias);
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn copy_from_reuses_and_reshapes() {
        let mut dst = Mat::zeros(8, 8);
        let cap = dst.data.capacity();
        let src = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        dst.copy_from(&src);
        assert_eq!((dst.rows, dst.cols), (2, 3));
        assert_eq!(dst.data, src.data);
        assert_eq!(dst.data.capacity(), cap, "no realloc when shrinking");
    }

    #[test]
    fn block_roundtrip_and_padding() {
        let a = Mat::from_fn(10, 10, |r, c| (r * 10 + c) as f32);
        let blk = a.block(8, 8, 8, 8);
        assert_eq!(blk.at(0, 0), 88.0);
        assert_eq!(blk.at(1, 1), 99.0);
        assert_eq!(blk.at(2, 2), 0.0); // padded
        let mut b = Mat::zeros(10, 10);
        b.set_block(8, 8, &blk);
        assert_eq!(b.at(9, 9), 99.0);
    }

    #[test]
    fn col_sums_match_manual() {
        let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.col_sums(), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn axpy_adds_scaled() {
        let mut a = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Mat::from_vec(1, 3, vec![10.0, 10.0, 10.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.data, vec![6.0, 7.0, 8.0]);
    }
}
