//! Minimal JSON emission (no serde offline).
//!
//! Only what the report writers need: objects, arrays, numbers, strings.
//! Produces deterministic key order (insertion order) so experiment
//! outputs diff cleanly between runs.

use std::fmt::Write as _;

/// A JSON value builder.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Insert a key into an object (panics on non-objects — builder misuse).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(kv) => kv.push((key.to_string(), val.into())),
            _ => panic!("set() on non-object"),
        }
        self
    }

    /// Push a value into an array.
    pub fn push(mut self, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Arr(xs) => xs.push(val.into()),
            _ => panic!("push() on non-array"),
        }
        self
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            if let Some(n) = indent {
                out.push('\n');
                for _ in 0..n * d {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !xs.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    Json::Str(k.clone()).write(out, None, 0);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !kv.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i32> for Json {
    fn from(x: i32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(xs: &[T]) -> Json {
        Json::Arr(xs.iter().cloned().map(Into::into).collect())
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_object() {
        let j = Json::obj().set("a", 1i64).set("b", "x").set("c", Json::arr().push(1.5f64));
        assert_eq!(j.to_string(), r#"{"a":1,"b":"x","c":[1.5]}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn pretty_round_trips_structure() {
        let j = Json::obj().set("xs", vec![1i64, 2, 3]);
        let p = j.pretty();
        assert!(p.contains("\n"));
        assert!(p.contains("\"xs\""));
    }
}
