//! Minimal JSON emission and parsing (no serde offline).
//!
//! Emission covers what the report writers need: objects, arrays,
//! numbers, strings, deterministic key order (insertion order) so
//! experiment outputs diff cleanly between runs. [`Json::parse`] is the
//! inverse — a small recursive-descent reader used by the conformance
//! suite to load committed golden-vector files and by tests that
//! inspect report documents structurally instead of by substring.

#![forbid(unsafe_code)]

use std::fmt::Write as _;

/// A JSON value builder.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Insert a key into an object (panics on non-objects — builder misuse).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(kv) => kv.push((key.to_string(), val.into())),
            _ => panic!("set() on non-object"),
        }
        self
    }

    /// Push a value into an array.
    pub fn push(mut self, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Arr(xs) => xs.push(val.into()),
            _ => panic!("push() on non-array"),
        }
        self
    }

    /// Object field lookup (None on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Array elements (None on non-arrays).
    pub fn items(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Object entries in document order (None on non-objects).
    pub fn entries(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Some(kv),
            _ => None,
        }
    }

    /// Numeric value (None on non-numbers).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// String value (None on non-strings).
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value (None on non-booleans).
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Parse a JSON document (the inverse of [`Json::to_string`] /
    /// [`Json::pretty`]). Strict enough for round-trips and committed
    /// test vectors: rejects trailing garbage, unterminated strings,
    /// bad escapes, and malformed numbers with a byte offset.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing characters at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            if let Some(n) = indent {
                out.push('\n');
                for _ in 0..n * d {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !xs.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    Json::Str(k.clone()).write(out, None, 0);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !kv.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Maximum array/object nesting depth [`Json::parse`] accepts. The
/// reader recurses once per level, so without a cap a pathological
/// `[[[[...` golden/report file overflows the thread stack; 128 levels
/// is far beyond any document this crate emits.
const MAX_DEPTH: usize = 128;

/// Recursive-descent JSON reader over raw bytes.
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl Parser<'_> {
    fn enter(&mut self) -> Result<(), String> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(format!("nesting depth {MAX_DEPTH} exceeded at byte {}", self.pos));
        }
        Ok(())
    }

    fn leave(&mut self) {
        self.depth -= 1;
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(b) => Err(format!("unexpected `{}` at byte {}", b as char, self.pos)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        self.enter()?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.leave();
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.leave();
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(format!("expected `,` or `]` at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        self.enter()?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.leave();
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            kv.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b'}') => {
                    self.pos += 1;
                    self.leave();
                    return Ok(Json::Obj(kv));
                }
                Some(b',') => self.pos += 1,
                _ => return Err(format!("expected `,` or `}}` at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                return Err("unterminated string".into());
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        return Err("unterminated escape".into());
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| "non-utf8 \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape `{hex}`"))?;
                            // BMP only — the emitter never writes surrogate pairs
                            let ch = char::from_u32(code)
                                .ok_or_else(|| format!("invalid codepoint \\u{hex}"))?;
                            out.push(ch);
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape `\\{}`", other as char)),
                    }
                }
                _ => {
                    // consume the full UTF-8 sequence starting here
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if len == 0 || end > self.bytes.len() {
                        return Err(format!("invalid utf-8 at byte {start}"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| format!("invalid utf-8 at byte {start}"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let tok = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii digits");
        tok.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number `{tok}` at byte {start}"))
    }
}

/// Byte length of the UTF-8 sequence that starts with `b` (0 = invalid
/// start byte).
fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 0,
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i32> for Json {
    fn from(x: i32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(xs: &[T]) -> Json {
        Json::Arr(xs.iter().cloned().map(Into::into).collect())
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_object() {
        let j = Json::obj().set("a", 1i64).set("b", "x").set("c", Json::arr().push(1.5f64));
        assert_eq!(j.to_string(), r#"{"a":1,"b":"x","c":[1.5]}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn pretty_round_trips_structure() {
        let j = Json::obj().set("xs", vec![1i64, 2, 3]);
        let p = j.pretty();
        assert!(p.contains("\n"));
        assert!(p.contains("\"xs\""));
    }

    #[test]
    fn parse_inverts_emission() {
        let j = Json::obj()
            .set("name", "mx-e4m3")
            .set("n", -12i64)
            .set("x", 0.001953125f64)
            .set("big", 5.7344e4f64)
            .set("flag", true)
            .set("none", Json::Null)
            .set("xs", Json::arr().push(1i64).push(Json::arr().push("a\"b\\c\nd")))
            .set("o", Json::obj().set("k", 2i64));
        for text in [j.to_string(), j.pretty()] {
            let p = Json::parse(&text).unwrap();
            assert_eq!(p.to_string(), j.to_string(), "{text}");
        }
    }

    #[test]
    fn parse_accessors_walk_documents() {
        let p = Json::parse(r#"{"a": {"b": [1, 2.5, "x", true]}, "z": null}"#).unwrap();
        let xs = p.get("a").and_then(|a| a.get("b")).and_then(|b| b.items()).unwrap();
        assert_eq!(xs.len(), 4);
        assert_eq!(xs[0].as_f64(), Some(1.0));
        assert_eq!(xs[1].as_f64(), Some(2.5));
        assert_eq!(xs[2].as_str(), Some("x"));
        assert_eq!(xs[3].as_bool(), Some(true));
        assert!(matches!(p.get("z"), Some(Json::Null)));
        assert_eq!(p.get("missing").map(|_| ()), None);
        assert_eq!(p.entries().unwrap().len(), 2);
    }

    #[test]
    fn parse_handles_numbers_and_escapes() {
        assert_eq!(Json::parse("-1.5e-3").unwrap().as_f64(), Some(-0.0015));
        assert_eq!(Json::parse("1e-40").unwrap().as_f64(), Some(1e-40));
        assert_eq!(Json::parse(r#""A\t""#).unwrap().as_str(), Some("A\t"));
        assert_eq!(Json::parse("\"caf\u{e9}\"").unwrap().as_str(), Some("caf\u{e9}"));
    }

    #[test]
    fn parse_caps_nesting_depth() {
        // far past the limit: must be a structured error, not a stack
        // overflow
        let deep = "[".repeat(4000) + &"]".repeat(4000);
        let err = Json::parse(&deep).unwrap_err();
        assert!(err.contains("nesting depth"), "{err}");
        let hostile = format!("{{\"a\": {}1{}}}", "[".repeat(4000), "]".repeat(4000));
        assert!(Json::parse(&hostile).unwrap_err().contains("nesting depth"));
        // well-formed documents inside the limit still parse
        let ok = "[".repeat(64) + &"]".repeat(64);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "{\"a\":}", "tru", "1 2", "\"unterminated",
            "\"bad\\q\"", "nope", "[1]]",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?}");
        }
    }
}
