//! Small self-contained utilities.
//!
//! The offline build environment only vendors the `xla` crate's dependency
//! closure, so this module replaces the usual ecosystem crates:
//! [`rng`] stands in for `rand` (PCG64), [`json`] for `serde_json`
//! (emission only), [`bytes`] for `bincode` (the bounds-checked binary
//! codec under the MX checkpoint format), [`mat`] provides the dense f32
//! matrix the simulators and the golden trainer share, [`testing`]
//! provides the hand-rolled property-test loop used across the test
//! suite, and [`par`] stands in for `rayon` (block-parallel fork-join
//! with bit-identical results).

pub mod bytes;
pub mod json;
pub mod mat;
pub mod par;
pub mod rng;
pub mod testing;

pub use mat::Mat;
pub use rng::Pcg64;
