//! Little-endian binary (de)serialization for checkpoint files.
//!
//! The offline dependency closure has no `serde`/`bincode`, so the MX
//! checkpoint format (`trainer::checkpoint`) is hand-rolled over these
//! two primitives. [`ByteWriter`] appends fixed-width little-endian
//! scalars, length-prefixed strings/slices, and bit-packed sub-byte code
//! streams; [`ByteReader`] is its bounds-checked inverse — every read
//! returns `Result`, so corrupt or truncated files surface as errors
//! instead of panics.
//!
//! f32/f64 round-trip through `to_le_bytes`/`from_le_bytes`, i.e. the
//! exact bit pattern: checkpoint restore is bitwise lossless, which is
//! what makes save/resume training indistinguishable from an
//! uninterrupted run (asserted by `tests/checkpoint.rs`).

#![forbid(unsafe_code)]

/// FNV-1a 64-bit hash — the store layer's chunk/index checksum.
///
/// Dependency-free and deterministic across platforms (it walks bytes,
/// not words). This is a *corruption* detector for shard chunks and
/// trailing indexes (`store::shard`), not a cryptographic MAC: a
/// flipped byte or a truncated range is caught with overwhelming
/// probability, an adversary is out of scope.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Append-only little-endian byte buffer.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_i8(&mut self, v: i8) {
        self.buf.push(v as u8);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// u32-length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// u64-length-prefixed raw byte slice (the store layer's embedded
    /// chunk payloads).
    pub fn put_bytes(&mut self, bytes: &[u8]) {
        self.put_u64(bytes.len() as u64);
        self.buf.extend_from_slice(bytes);
    }

    /// u64-length-prefixed f32 slice (raw bit patterns — lossless).
    pub fn put_f32s(&mut self, xs: &[f32]) {
        self.put_u64(xs.len() as u64);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Bit-pack `codes` at `bits` bits each (MSB-first within the
    /// stream), padding the final partial byte with zero bits. `bits`
    /// must be 1..=8 and every code must fit in `bits` bits.
    pub fn put_packed(&mut self, codes: impl Iterator<Item = u8>, bits: u32) {
        debug_assert!((1..=8).contains(&bits));
        let mask = if bits == 8 { 0xFF } else { (1u32 << bits) - 1 };
        let mut acc: u32 = 0;
        let mut n: u32 = 0;
        for c in codes {
            debug_assert_eq!(c as u32 & mask, c as u32, "code wider than {bits} bits");
            acc = (acc << bits) | (c as u32 & mask);
            n += bits;
            while n >= 8 {
                n -= 8;
                self.buf.push((acc >> n) as u8);
            }
        }
        if n > 0 {
            self.buf.push((acc << (8 - n)) as u8);
        }
    }
}

/// Bounds-checked little-endian reader over a byte slice.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated input: need {n} bytes at offset {}, {} left",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn get_i8(&mut self) -> Result<i8, String> {
        Ok(self.take(1)?[0] as i8)
    }

    pub fn get_u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_str(&mut self) -> Result<String, String> {
        let n = self.get_u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| format!("invalid UTF-8 string: {e}"))
    }

    /// Inverse of [`ByteWriter::put_bytes`].
    pub fn get_bytes(&mut self) -> Result<Vec<u8>, String> {
        let n = self.get_u64()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub fn get_f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.get_u64()? as usize;
        let bytes = self.take(n.checked_mul(4).ok_or("f32 slice length overflow")?)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Inverse of [`ByteWriter::put_packed`]: read `count` codes of
    /// `bits` bits each.
    pub fn get_packed(&mut self, count: usize, bits: u32) -> Result<Vec<u8>, String> {
        debug_assert!((1..=8).contains(&bits));
        let total_bits = count.checked_mul(bits as usize).ok_or("packed length overflow")?;
        let nbytes = total_bits.div_ceil(8);
        let bytes = self.take(nbytes)?;
        let mask = if bits == 8 { 0xFF } else { (1u32 << bits) - 1 };
        let mut out = Vec::with_capacity(count);
        let mut acc: u32 = 0;
        let mut n: u32 = 0;
        let mut next = bytes.iter();
        for _ in 0..count {
            while n < bits {
                acc = (acc << 8) | *next.next().expect("sized above") as u32;
                n += 8;
            }
            n -= bits;
            out.push(((acc >> n) & mask) as u8);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_round_trip() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_i8(-3);
        w.put_u32(0xDEADBEEF);
        w.put_u64(u64::MAX - 1);
        w.put_f32(-0.0);
        w.put_f64(f64::MIN_POSITIVE);
        w.put_str("héllo");
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_i8().unwrap(), -3);
        assert_eq!(r.get_u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.get_f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.get_f64().unwrap(), f64::MIN_POSITIVE);
        assert_eq!(r.get_str().unwrap(), "héllo");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn f32_slice_is_bitwise_lossless() {
        let xs = vec![1.0f32, -1.5e-38, f32::MAX, 3.3333333, 0.1];
        let mut w = ByteWriter::new();
        w.put_f32s(&xs);
        let bytes = w.into_bytes();
        let got = ByteReader::new(&bytes).get_f32s().unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&got), bits(&xs));
    }

    #[test]
    fn packed_codes_round_trip_all_widths() {
        for bits in [1u32, 4, 6, 8] {
            let mask = if bits == 8 { 0xFF } else { (1u8 << bits) - 1 };
            let codes: Vec<u8> = (0..100u32).map(|i| (i * 37 % 251) as u8 & mask).collect();
            let mut w = ByteWriter::new();
            w.put_packed(codes.iter().copied(), bits);
            let expect_bytes = (codes.len() * bits as usize).div_ceil(8);
            let bytes = w.into_bytes();
            assert_eq!(bytes.len(), expect_bytes, "{bits}-bit packing density");
            let got = ByteReader::new(&bytes).get_packed(codes.len(), bits).unwrap();
            assert_eq!(got, codes, "{bits}-bit");
        }
    }

    #[test]
    fn raw_byte_slices_round_trip() {
        let payload = vec![0u8, 255, 42, 7];
        let mut w = ByteWriter::new();
        w.put_bytes(&payload);
        w.put_bytes(&[]);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_bytes().unwrap(), payload);
        assert_eq!(r.get_bytes().unwrap(), Vec::<u8>::new());
        assert_eq!(r.remaining(), 0);
        // declared length past the buffer end errors
        let mut w = ByteWriter::new();
        w.put_u64(1 << 40);
        let bytes = w.into_bytes();
        assert!(ByteReader::new(&bytes).get_bytes().is_err());
    }

    #[test]
    fn fnv1a64_matches_reference_vectors_and_detects_flips() {
        // published FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
        // any single-byte flip must change the digest
        let base = fnv1a64(b"mxscale shard chunk");
        let mut tampered = b"mxscale shard chunk".to_vec();
        tampered[3] ^= 0x01;
        assert_ne!(base, fnv1a64(&tampered));
    }

    #[test]
    fn truncated_input_errors_instead_of_panicking() {
        let mut w = ByteWriter::new();
        w.put_u64(42);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes[..5]);
        assert!(r.get_u64().is_err());
        // string whose declared length exceeds the buffer
        let mut w = ByteWriter::new();
        w.put_u32(1000);
        let bytes = w.into_bytes();
        assert!(ByteReader::new(&bytes).get_str().is_err());
        // packed stream shorter than the requested code count
        let mut w = ByteWriter::new();
        w.put_packed([1u8, 2, 3].iter().copied(), 4);
        let bytes = w.into_bytes();
        assert!(ByteReader::new(&bytes).get_packed(10, 4).is_err());
    }
}
