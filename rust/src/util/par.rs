//! The batched parallel engine: rayon-style fork-join over OS threads.
//!
//! The OCP MX block structure makes every hot path in this crate
//! embarrassingly parallel by construction — blocks share nothing but a
//! read-only input, PE-array output tiles are independent, and QAT runs
//! in a precision sweep never touch each other's state. `rayon` itself
//! cannot be vendored in the offline dependency closure, so this module
//! provides the two primitives the simulators need with identical
//! semantics on `std::thread::scope`:
//!
//! * [`par_map`] — indexed map producing a `Vec` in input order, with
//!   dynamic (atomic work-counter) load balancing;
//! * [`par_chunks_mut`] — disjoint in-place chunk processing of a slice
//!   (row bands of a matrix, tiles of a tensor).
//!
//! **Determinism contract:** callers only hand these primitives work
//! items that are mutually independent and write disjoint outputs, so
//! every parallel result is *bit-identical* to the serial loop it
//! replaces (asserted by `tests/parallel.rs`). Worker count comes from
//! `RAYON_NUM_THREADS` (rayon's knob, honored for familiarity) or
//! `MXSCALE_THREADS`, defaulting to the machine's available parallelism;
//! setting it to 1 recovers fully serial execution.
//!
//! Nested parallel regions degrade to serial automatically (a worker
//! thread never forks again), so batch-level parallelism (e.g.
//! [`crate::trainer::batched::BatchedTrainer`]) composes with
//! block-level parallelism without oversubscribing the machine.

#![forbid(unsafe_code)]

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

static THREADS: OnceLock<usize> = OnceLock::new();

thread_local! {
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// Worker-thread count used by the parallel primitives.
///
/// `RAYON_NUM_THREADS` (or `MXSCALE_THREADS`) if set to a positive
/// integer, else `std::thread::available_parallelism()`. Cached for the
/// process lifetime, mirroring rayon's global-pool semantics.
pub fn threads() -> usize {
    *THREADS.get_or_init(|| {
        for var in ["RAYON_NUM_THREADS", "MXSCALE_THREADS"] {
            if let Some(v) = std::env::var_os(var) {
                if let Ok(n) = v.to_string_lossy().trim().parse::<usize>() {
                    if n >= 1 {
                        return n;
                    }
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// True while executing inside a worker of an enclosing parallel region.
pub fn in_parallel_region() -> bool {
    IN_POOL.with(|c| c.get())
}

fn enter_pool() {
    IN_POOL.with(|c| c.set(true));
}

/// Mark the calling thread as a worker of a parallel region for the
/// rest of its life: nested [`par_map`]/[`par_chunks_mut`] calls made
/// from it degrade to their serial twins.
///
/// Executors layered above the fork-join primitives (the serving
/// front-end in `crate::serve` runs its own worker threads) call this
/// once per worker, so the block-level parallelism *inside* a training
/// session composes with session-level parallelism without
/// oversubscribing the machine — the same nested-region rule the
/// pool's own workers follow.
pub fn enter_worker() {
    enter_pool();
}

/// The serial reference for [`par_map`]: a plain in-order map. The
/// parallel path degrades to exactly this loop, so the two are
/// bit-identical by construction (`tests/parallel.rs` asserts it).
pub fn par_map_serial<T>(n: usize, f: impl Fn(usize) -> T) -> Vec<T> {
    (0..n).map(f).collect()
}

/// Map `f` over `0..n`, returning results in index order.
///
/// Runs serially when `n < min_par`, when only one worker thread is
/// configured, or when already inside a parallel region; otherwise
/// distributes contiguous index chunks over the worker pool with an
/// atomic grab counter (dynamic load balancing — uneven items like
/// training sessions of different step counts still pack well).
pub fn par_map<T, F>(n: usize, min_par: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let nt = threads();
    if nt <= 1 || n < min_par.max(2) || in_parallel_region() {
        return par_map_serial(n, f);
    }
    let workers = nt.min(n);
    // ~4 chunks per worker: coarse enough to amortize the grab, fine
    // enough that a slow chunk does not serialize the tail.
    let chunk = (n / (workers * 4)).max(1);
    let next = AtomicUsize::new(0);
    let mut parts: Vec<(usize, Vec<T>)> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| {
                    enter_pool();
                    let mut out: Vec<(usize, Vec<T>)> = Vec::new();
                    loop {
                        let start = next.fetch_add(chunk, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        let end = (start + chunk).min(n);
                        out.push((start, (start..end).map(&f).collect()));
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(mut p) => parts.append(&mut p),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    parts.sort_unstable_by_key(|p| p.0);
    let mut v = Vec::with_capacity(n);
    for (_, mut p) in parts {
        v.append(&mut p);
    }
    v
}

/// Process disjoint `chunk_len`-sized chunks of `data` in parallel.
///
/// `f(i, chunk)` receives the chunk index (chunk `i` starts at element
/// `i * chunk_len`) and the mutable chunk. Runs serially when fewer than
/// `min_par_chunks` chunks exist, when one worker is configured, or when
/// nested inside a parallel region. Chunks are handed out through a
/// mutex-guarded iterator — contention is negligible at matrix-band
/// granularity and the borrow checker proves disjointness.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, min_par_chunks: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let chunk_len = chunk_len.max(1);
    let n_chunks = data.len().div_ceil(chunk_len);
    let nt = threads();
    if nt <= 1 || n_chunks < min_par_chunks.max(2) || in_parallel_region() {
        par_chunks_mut_serial(data, chunk_len, f);
        return;
    }
    let workers = nt.min(n_chunks);
    let work = Mutex::new(data.chunks_mut(chunk_len).enumerate());
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| {
                    enter_pool();
                    loop {
                        let item = work.lock().unwrap().next();
                        match item {
                            Some((i, c)) => f(i, c),
                            None => break,
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            if let Err(payload) = h.join() {
                std::panic::resume_unwind(payload);
            }
        }
    });
}

/// The serial reference for [`par_chunks_mut`]: in-order chunk
/// processing on the calling thread — exactly the loop the parallel
/// path degrades to, so the twins are bit-identical by construction.
pub fn par_chunks_mut_serial<T>(data: &mut [T], chunk_len: usize, f: impl Fn(usize, &mut [T])) {
    let chunk_len = chunk_len.max(1);
    for (i, c) in data.chunks_mut(chunk_len).enumerate() {
        f(i, c);
    }
}

/// Per-worker work-stealing deques: the scheduling substrate for
/// executors layered above the fork-join primitives (the serving
/// front-end keeps cores saturated under session churn with it).
///
/// Each worker owns deque `w`: [`WorkStealQueues::push`] and
/// [`WorkStealQueues::pop`] touch only that deque (LIFO, so a session's
/// consecutive quanta stay cache-hot on one core), while
/// [`WorkStealQueues::steal`] scans the *other* deques round-robin from
/// the thief's index and takes the oldest item (FIFO) — the classic
/// work-stealing discipline, carried by mutexed `VecDeque`s because the
/// offline dependency closure has no lock-free deque and contention at
/// session/quantum granularity is negligible.
///
/// Determinism: an item lives in exactly one deque (or is owned by
/// exactly one worker) at any moment, so whatever interleaving the
/// steals produce, each item's own processing history is a serial
/// sequence — the property the fleet bit-identity contract rides on.
pub struct WorkStealQueues<T> {
    queues: Vec<Mutex<std::collections::VecDeque<T>>>,
}

impl<T> WorkStealQueues<T> {
    /// One deque per worker (at least one).
    pub fn new(workers: usize) -> Self {
        let queues =
            (0..workers.max(1)).map(|_| Mutex::new(std::collections::VecDeque::new())).collect();
        Self { queues }
    }

    /// Number of worker deques.
    pub fn workers(&self) -> usize {
        self.queues.len()
    }

    fn guard(&self, w: usize) -> std::sync::MutexGuard<'_, std::collections::VecDeque<T>> {
        match self.queues[w % self.queues.len()].lock() {
            Ok(g) => g,
            // a poisoned deque only means another worker panicked while
            // holding the lock; the queue itself is still well-formed
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Push onto worker `w`'s own deque (newest end).
    pub fn push(&self, w: usize, item: T) {
        self.guard(w).push_back(item);
    }

    /// Pop worker `w`'s own newest item (LIFO).
    pub fn pop(&self, w: usize) -> Option<T> {
        self.guard(w).pop_back()
    }

    /// Steal the *oldest* item from another worker's deque, scanning
    /// round-robin from the thief's index. Returns `None` when every
    /// other deque is empty.
    pub fn steal(&self, thief: usize) -> Option<T> {
        let n = self.queues.len();
        for k in 1..n {
            let victim = (thief + k) % n;
            if let Some(item) = self.guard(victim).pop_front() {
                return Some(item);
            }
        }
        None
    }

    /// Items currently queued across every deque.
    pub fn len(&self) -> usize {
        (0..self.queues.len()).map(|w| self.guard(w).len()).sum()
    }

    /// True when every deque is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threads_is_positive() {
        assert!(threads() >= 1);
    }

    #[test]
    fn par_map_preserves_order() {
        let got = par_map(1000, 1, |i| i * 2);
        let want: Vec<usize> = (0..1000).map(|i| i * 2).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_map_empty_and_single() {
        assert_eq!(par_map(0, 1, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, 1, |i| i + 7), vec![7]);
    }

    #[test]
    fn par_map_serial_below_threshold_matches() {
        let a = par_map(10, 1000, |i| i * i);
        let b = par_map(10, 1, |i| i * i);
        assert_eq!(a, b);
    }

    #[test]
    fn par_chunks_mut_covers_every_element_once() {
        let mut data = vec![0u32; 10_007];
        par_chunks_mut(&mut data, 97, 2, |i, chunk| {
            for v in chunk.iter_mut() {
                *v += 1 + i as u32;
            }
        });
        for (j, &v) in data.iter().enumerate() {
            assert_eq!(v, 1 + (j / 97) as u32, "element {j}");
        }
    }

    #[test]
    fn par_chunks_mut_empty_slice() {
        let mut data: Vec<u8> = Vec::new();
        par_chunks_mut(&mut data, 8, 2, |_, _| panic!("no chunks expected"));
    }

    #[test]
    fn nested_regions_degrade_to_serial() {
        let outer = par_map(8, 2, |i| {
            // inner call must not fork again; it still computes correctly
            let inner = par_map(16, 2, |j| i * 100 + j);
            inner.iter().sum::<usize>()
        });
        let want: Vec<usize> = (0..8).map(|i| (0..16).map(|j| i * 100 + j).sum()).collect();
        assert_eq!(outer, want);
    }

    #[test]
    fn work_steal_queues_own_pop_is_lifo_steal_is_fifo() {
        let q: WorkStealQueues<u32> = WorkStealQueues::new(2);
        q.push(0, 1);
        q.push(0, 2);
        q.push(0, 3);
        assert_eq!(q.len(), 3);
        // the owner pops its newest item; a thief takes the oldest
        assert_eq!(q.pop(0), Some(3));
        assert_eq!(q.steal(1), Some(1));
        assert_eq!(q.pop(0), Some(2));
        assert_eq!(q.pop(0), None);
        assert_eq!(q.steal(1), None);
        assert!(q.is_empty());
    }

    #[test]
    fn work_steal_queues_deliver_every_item_exactly_once() {
        let q = std::sync::Arc::new(WorkStealQueues::<usize>::new(4));
        for i in 0..1000 {
            q.push(i % 4, i);
        }
        let seen = std::sync::Mutex::new(vec![0u8; 1000]);
        std::thread::scope(|s| {
            for w in 0..4 {
                let q = &q;
                let seen = &seen;
                s.spawn(move || loop {
                    let item = q.pop(w).or_else(|| q.steal(w));
                    match item {
                        Some(i) => seen.lock().unwrap()[i] += 1,
                        None => break,
                    }
                });
            }
        });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1), "lost or duplicated items");
    }

    #[test]
    fn enter_worker_degrades_nested_regions_to_serial() {
        std::thread::scope(|s| {
            s.spawn(|| {
                assert!(!in_parallel_region());
                enter_worker();
                assert!(in_parallel_region());
            });
        });
    }

    #[test]
    fn par_map_with_uneven_work_is_correct() {
        // items of very different cost still land in order
        let got = par_map(64, 2, |i| {
            let mut acc = 0u64;
            for k in 0..(i % 7) * 10_000 {
                acc = acc.wrapping_add(k as u64);
            }
            (i, acc)
        });
        for (i, &(idx, _)) in got.iter().enumerate() {
            assert_eq!(i, idx);
        }
    }
}
