//! The GeMM core: functional execution + cycle/event/energy accounting.
//!
//! Functionally, the 4x16 grid computes the same numbers whichever grid
//! slot a tile lands on, so the bit-exact datapath simulation walks the
//! output tiles sequentially (one [`PeArray`] reused), while the *timing*
//! comes from the grid-pass schedule in [`schedule`] and the *energy*
//! from the aggregated event counts.

#![forbid(unsafe_code)]

use crate::arith::{Events, MacVariant};
use crate::gemmcore::quantizer::Quantizer;
use crate::gemmcore::schedule::{self, CycleCost};
use crate::mx::element::ElementFormat;
use crate::mx::tensor::MxTensor;
use crate::pearray::PeArray;
use crate::util::mat::Mat;

/// The learning-enabled MX GeMM core.
#[derive(Debug)]
pub struct GemmCore {
    pub format: ElementFormat,
    pub variant: MacVariant,
    pe: PeArray,
    pub quantizer: Quantizer,
    /// Accumulated schedule cost across calls.
    pub cost: CycleCost,
}

impl GemmCore {
    pub fn new(format: ElementFormat) -> Self {
        Self::with_variant(format, MacVariant::ExtMantissaBypass)
    }

    pub fn with_variant(format: ElementFormat, variant: MacVariant) -> Self {
        Self {
            format,
            variant,
            pe: PeArray::new(format, variant),
            quantizer: Quantizer::new(),
            cost: CycleCost::default(),
        }
    }

    /// Bit-exact GeMM of two square-quantized tensors, with schedule
    /// accounting. Returns the FP32 result matrix.
    ///
    /// Large GeMMs run tile-parallel inside the PE array (independent
    /// output tiles, per-worker contexts, `Events` reduction); the
    /// simulated cycle/cost model is untouched by host parallelism.
    pub fn gemm(&mut self, qa: &MxTensor, qb: &MxTensor) -> Mat {
        self.gemm_staged(qa, qb, schedule::Stage::Forward)
    }

    /// [`GemmCore::gemm`] with an explicit training stage, so the
    /// schedule charges the stage's writeback path (quantized for
    /// forward/backward, serialized FP32 for weight gradients — the
    /// paper's §IV-B utilization collapse). The training backends route
    /// every GeMM through here.
    pub fn gemm_staged(&mut self, qa: &MxTensor, qb: &MxTensor, stage: schedule::Stage) -> Mat {
        let out = self.pe.gemm_quantized(qa, qb);
        self.cost.add(&schedule::gemm_cycles_staged(qa.rows, qa.cols, qb.cols, self.format, stage));
        out
    }

    /// Serial reference GeMM — identical numbers, events, and cost as
    /// [`GemmCore::gemm`]; kept for identity tests and as the benchmark
    /// baseline the parallel walk is measured against.
    pub fn gemm_serial(&mut self, qa: &MxTensor, qb: &MxTensor) -> Mat {
        let out = self.pe.gemm_quantized_serial(qa, qb);
        self.cost.add(&schedule::gemm_cycles(qa.rows, qa.cols, qb.cols, self.format));
        out
    }

    /// Quantize-then-GeMM convenience over dense matrices.
    pub fn gemm_dense(&mut self, a: &Mat, b: &Mat) -> Mat {
        let qa = self.quantizer.quantize(a, self.format);
        let qb = self.quantizer.quantize(b, self.format);
        self.gemm(&qa, &qb)
    }

    /// GeMM against a stored quantized weight's transpose — the backprop
    /// path that square blocks make free (no requantization).
    pub fn gemm_transposed_weight(&mut self, qe: &MxTensor, qw: &MxTensor) -> Mat {
        let qwt = qw.transpose().expect("square layout");
        self.gemm(qe, &qwt)
    }

    /// Drain datapath event counters.
    pub fn take_events(&mut self) -> Events {
        self.pe.take_events()
    }

    /// Peek datapath event counters.
    pub fn events(&self) -> Events {
        self.pe.events()
    }

    /// Simulated datapath cycles consumed by the PE array walk
    /// (per-tile; the grid schedule in `cost` is the wall-clock model).
    pub fn pe_cycles(&self) -> u64 {
        self.pe.cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::tensor::Layout;
    use crate::util::rng::Pcg64;

    #[test]
    fn gemm_matches_dequantized_reference() {
        let mut rng = Pcg64::new(11);
        let a = Mat::randn(32, 64, 1.0, &mut rng);
        let b = Mat::randn(64, 32, 1.0, &mut rng);
        let mut core = GemmCore::new(ElementFormat::E4M3);
        let qa = MxTensor::quantize(&a, ElementFormat::E4M3, Layout::Square8x8);
        let qb = MxTensor::quantize(&b, ElementFormat::E4M3, Layout::Square8x8);
        let out = core.gemm(&qa, &qb);
        let golden = qa.dequantize().matmul(&qb.dequantize());
        assert!(out.mse(&golden).sqrt() < golden.max_abs() as f64 * 1e-5);
        assert!(core.cost.total() > 0);
        assert_eq!(core.cost.mul_ops, 32 * 64 * 32);
    }

    #[test]
    fn backprop_via_transposed_weight_matches_reference() {
        let mut rng = Pcg64::new(12);
        let w = Mat::randn(64, 32, 1.0, &mut rng);
        let e = Mat::randn(16, 32, 1.0, &mut rng);
        let mut core = GemmCore::new(ElementFormat::Int8);
        let qw = MxTensor::quantize(&w, ElementFormat::Int8, Layout::Square8x8);
        let qe = MxTensor::quantize(&e, ElementFormat::Int8, Layout::Square8x8);
        let out = core.gemm_transposed_weight(&qe, &qw);
        let golden = qe.dequantize().matmul(&qw.dequantize().transpose());
        assert!(out.mse(&golden).sqrt() < golden.max_abs().max(1.0) as f64 * 1e-5);
    }

    #[test]
    fn cost_accumulates_across_calls() {
        let mut rng = Pcg64::new(13);
        let a = Mat::randn(8, 8, 1.0, &mut rng);
        let b = Mat::randn(8, 8, 1.0, &mut rng);
        let mut core = GemmCore::new(ElementFormat::E2M1);
        core.gemm_dense(&a, &b);
        let c1 = core.cost.total();
        core.gemm_dense(&a, &b);
        assert_eq!(core.cost.total(), 2 * c1);
    }
}
