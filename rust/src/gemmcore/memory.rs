//! On-chip memory-footprint accounting (regenerates Table III).
//!
//! The paper compares, for the pusher MLP (32-256-256-256-32), what each
//! design must keep resident for inference and training:
//!
//! * **FP32**: full weights; training adds stored activations (for all
//!   layer inputs) and one layer's error buffer.
//! * **Dacapo** (vector blocks): quantized W **and** a second quantized
//!   Wᵀ copy (row grouping differs after transposition), a single-layer
//!   activation ping-pong buffer for inference, all stored activations
//!   Aᵀ for training, and a column-grouped error copy; the row-grouped
//!   error reuses the activation buffer.
//! * **Ours** (square blocks): one W copy serves both passes (transpose
//!   is a block permutation), activations are stored once, and the error
//!   buffer needs no second grouping. Inference buffers stream (0 KB
//!   resident beyond W), matching the paper's accounting convention.

#![forbid(unsafe_code)]

use crate::mx::dacapo::DacapoFormat;
use crate::mx::element::ElementFormat;
use crate::mx::tensor::Layout;
use crate::mx::MxFormat;

/// An MLP shape: `dims[0]` inputs, `dims.last()` outputs.
#[derive(Debug, Clone)]
pub struct MlpShape {
    pub dims: Vec<usize>,
}

impl MlpShape {
    pub fn pusher() -> Self {
        Self { dims: vec![32, 256, 256, 256, 32] }
    }

    /// Total weight parameters.
    pub fn weight_params(&self) -> usize {
        self.dims.windows(2).map(|w| w[0] * w[1]).sum()
    }

    /// Activation elements stored for backprop: every layer *input*
    /// (including the network input), per sample.
    pub fn activation_elems_per_sample(&self) -> usize {
        self.dims[..self.dims.len() - 1].iter().sum()
    }

    /// Widest layer (error-buffer sizing).
    pub fn max_dim(&self) -> usize {
        *self.dims.iter().max().unwrap()
    }
}

/// One row of Table III, in KB (1 KB = 1024 bytes).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Footprint {
    pub w: f64,
    pub a_inference: f64,
    pub w_t: f64,
    pub a_t_training: f64,
    pub e_row: f64,
    pub e_col: f64,
}

impl Footprint {
    pub fn total(&self) -> f64 {
        self.w + self.a_inference + self.w_t + self.a_t_training + self.e_row + self.e_col
    }
}

fn kb(elems: usize, bits_per_elem: f64) -> f64 {
    elems as f64 * bits_per_elem / 8.0 / 1024.0
}

/// FP32 baseline row.
pub fn footprint_fp32(shape: &MlpShape, batch: usize) -> Footprint {
    Footprint {
        w: kb(shape.weight_params(), 32.0),
        a_inference: 0.0,
        w_t: 0.0, // FP32 transposes on the fly (no quantization grouping)
        a_t_training: kb(shape.activation_elems_per_sample() * batch, 32.0),
        e_row: kb(shape.max_dim() * batch, 32.0),
        e_col: 0.0,
    }
}

/// Dacapo row: MX9 vector blocks, two weight copies, col-grouped E copy.
pub fn footprint_dacapo(shape: &MlpShape, batch: usize, fmt: DacapoFormat) -> Footprint {
    let bpe = fmt.bits_per_element();
    Footprint {
        w: kb(shape.weight_params(), bpe),
        a_inference: kb(shape.max_dim() * batch, bpe), // ping-pong buffer
        w_t: kb(shape.weight_params(), bpe),           // second quantized copy
        a_t_training: kb(shape.activation_elems_per_sample() * batch, bpe),
        e_row: 0.0, // reuses the inference activation buffer
        e_col: kb(shape.max_dim() * batch, bpe), // column-grouped copy
    }
}

/// Our row: square blocks — single W, single A, single E grouping.
pub fn footprint_ours(shape: &MlpShape, batch: usize, fmt: ElementFormat) -> Footprint {
    let bpe = MxFormat { element: fmt, layout: Layout::Square8x8 }.bits_per_element();
    Footprint {
        w: kb(shape.weight_params(), bpe),
        a_inference: 0.0, // streamed; no second grouping needed
        w_t: 0.0,         // transpose is free (block permutation)
        a_t_training: kb(shape.activation_elems_per_sample() * batch, bpe),
        e_row: kb(shape.max_dim() * batch, bpe),
        e_col: 0.0, // same storage serves both dot-product directions
    }
}

/// Memory-interface traffic of one scheduled GeMM `[m,k] x [k,n]`, in
/// bits, consistent with the pass schedule in [`crate::gemmcore::schedule`]:
/// per block-step the grid reads one quantized tile per row and per
/// column; per pass it writes back 64 output tiles — quantized (element
/// width + shared exponent) for forward/backward stages, FP32 for weight
/// gradients, which leave for the weight-update accelerator. The
/// hardware training backend accumulates this per GeMM into its
/// [`crate::backend::HwCostReport`].
pub fn gemm_traffic_bits(
    m: usize,
    k: usize,
    n: usize,
    fmt: ElementFormat,
    stage: crate::gemmcore::schedule::Stage,
) -> u64 {
    use crate::gemmcore::schedule::{tile_bits, Stage};
    use crate::gemmcore::{GRID_COLS, GRID_ROWS};
    use crate::mx::tensor::SQ;
    let mb = m.div_ceil(SQ);
    let kb = k.div_ceil(SQ).max(1) as u64;
    let nb = n.div_ceil(SQ);
    let passes = (mb.div_ceil(GRID_ROWS) * nb.div_ceil(GRID_COLS)) as u64;
    let operand = passes * kb * (GRID_ROWS as u64 + GRID_COLS as u64) * tile_bits(fmt);
    let tiles = (GRID_ROWS * GRID_COLS) as u64;
    let writeback = passes
        * match stage {
            Stage::Forward | Stage::Backward => tiles * tile_bits(fmt),
            Stage::WeightGrad => tiles * 64 * 32,
        };
    operand + writeback
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemmcore::schedule::Stage;

    fn near(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn pusher_weight_count() {
        let s = MlpShape::pusher();
        assert_eq!(s.weight_params(), 32 * 256 + 256 * 256 + 256 * 256 + 256 * 32);
        assert_eq!(s.activation_elems_per_sample(), 32 + 256 + 256 + 256);
    }

    #[test]
    fn table3_fp32_rows() {
        let s = MlpShape::pusher();
        for (batch, a_t, e) in [(16, 50.0, 16.0), (32, 100.0, 32.0), (64, 200.0, 64.0)] {
            let f = footprint_fp32(&s, batch);
            assert!(near(f.w, 576.0, 0.1), "W {}", f.w);
            assert!(near(f.a_t_training, a_t, 0.1), "A^T {}", f.a_t_training);
            assert!(near(f.e_row, e, 0.1), "E {}", f.e_row);
        }
        assert!(near(footprint_fp32(&s, 32).total(), 708.0, 0.5));
    }

    #[test]
    fn table3_dacapo_rows() {
        let s = MlpShape::pusher();
        let f16 = footprint_dacapo(&s, 16, DacapoFormat::Mx9);
        assert!(near(f16.w, 162.0, 0.5), "W {}", f16.w);
        assert!(near(f16.w_t, 162.0, 0.5));
        assert!(near(f16.a_inference, 4.5, 0.1), "A {}", f16.a_inference);
        assert!(near(f16.a_t_training, 14.1, 0.2), "A^T {}", f16.a_t_training);
        assert!(near(f16.e_col, 4.5, 0.1));
        assert!(near(f16.total(), 347.1, 1.0), "total {}", f16.total());
        let f32b = footprint_dacapo(&s, 32, DacapoFormat::Mx9);
        assert!(near(f32b.total(), 370.1, 1.0), "total {}", f32b.total());
        let f64b = footprint_dacapo(&s, 64, DacapoFormat::Mx9);
        assert!(near(f64b.total(), 416.3, 1.0), "total {}", f64b.total());
    }

    #[test]
    fn table3_ours_rows() {
        let s = MlpShape::pusher();
        let f16 = footprint_ours(&s, 16, ElementFormat::Int8);
        assert!(near(f16.w, 146.3, 0.5), "W {}", f16.w);
        assert_eq!(f16.w_t, 0.0);
        assert!(near(f16.a_t_training, 12.7, 0.2), "A^T {}", f16.a_t_training);
        assert!(near(f16.e_row, 4.1, 0.1), "E {}", f16.e_row);
        assert!(near(f16.total(), 163.1, 1.0), "total {}", f16.total());
        let f32b = footprint_ours(&s, 32, ElementFormat::Int8);
        assert!(near(f32b.total(), 179.8, 1.0), "total {}", f32b.total());
        let f64b = footprint_ours(&s, 64, ElementFormat::Int8);
        assert!(near(f64b.total(), 213.4, 1.0), "total {}", f64b.total());
    }

    #[test]
    fn traffic_model_consistency() {
        // one 32x32x128 pass grid in INT8: 1 pass (4x16 covers 4x16
        // block-tiles), 16 K-steps, 20 tiles read per step
        let fmt = ElementFormat::Int8;
        let t = gemm_traffic_bits(32, 128, 128, fmt, Stage::Forward);
        let tile = 64 * 8 + 8;
        assert_eq!(t, 16 * 20 * tile + 64 * tile);
        // FP32 weight-gradient writeback dwarfs the quantized one
        let fwd = gemm_traffic_bits(256, 32, 256, fmt, Stage::Forward);
        let wg = gemm_traffic_bits(256, 32, 256, fmt, Stage::WeightGrad);
        assert!(wg > fwd);
        // narrower elements move fewer bits
        let t4 = gemm_traffic_bits(32, 128, 128, ElementFormat::E2M1, Stage::Forward);
        assert!(t4 < t);
    }

    #[test]
    fn headline_ratios() {
        // Dacapo needs 2.06x our memory; we are 3.94x below FP32 (B=32).
        let s = MlpShape::pusher();
        let ours = footprint_ours(&s, 32, ElementFormat::Int8).total();
        let dacapo = footprint_dacapo(&s, 32, DacapoFormat::Mx9).total();
        let fp32 = footprint_fp32(&s, 32).total();
        assert!(near(dacapo / ours, 2.06, 0.03), "{}", dacapo / ours);
        assert!(near(fp32 / ours, 3.94, 0.03), "{}", fp32 / ours);
        // 51% memory-footprint reduction headline
        assert!(near(1.0 - ours / dacapo, 0.51, 0.02), "{}", 1.0 - ours / dacapo);
    }
}
