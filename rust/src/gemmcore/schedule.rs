//! Cycle-accurate pass schedule of the GeMM core.
//!
//! A GeMM `C[M,N] = A[M,K] @ B[K,N]` is executed as grid passes: each
//! pass pins a 4x16 block-tile of C (output-stationary) and iterates the
//! K block dimension; per block-step the grid consumes 4 A-tiles and 16
//! B-tiles from memory. Three effects bound throughput:
//!
//! 1. **Compute**: 8 / 2 / 1 cycles per block-step (INT8 / FP8-6 / FP4).
//! 2. **Input bandwidth**: `(4 + 16) x (64*ebits + 8)` bits per step must
//!    fit in `5280 x step_cycles` bits — FP4 saturates this exactly
//!    (20 x 264 = 5280), FP8 nearly (20 x 520 / 2 = 5200), INT8 has
//!    ~4x headroom. This is why the paper calls the interface "fully
//!    utilized during FP8 and FP4 operations".
//! 3. **FP32 writeback**: each completed pass writes 64 tiles x 64
//!    elements x 32 bits = 131072 bits through the *same* interface;
//!    whatever does not fit in the pass's spare bandwidth stalls the
//!    array. Weight-gradient GeMMs accumulate over the small batch
//!    dimension (K = 32 -> 4 block-steps), so writebacks are frequent and
//!    utilization collapses — the paper's §IV-B observation.

#![forbid(unsafe_code)]

use crate::arith::Mode;
use crate::gemmcore::{BW_BITS_PER_CYCLE, GRID_COLS, GRID_ROWS};
use crate::mx::element::ElementFormat;
use crate::mx::tensor::SQ;

/// Training stage (distinct utilization patterns, paper §IV-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Forward,
    Backward,
    WeightGrad,
}

/// Cycle cost breakdown of a scheduled GeMM (or a whole training step).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CycleCost {
    /// Block-step compute cycles (array busy).
    pub compute: u64,
    /// Stall cycles waiting on operand bandwidth.
    pub input_stall: u64,
    /// Stall cycles waiting on FP32 writeback drain.
    pub writeback_stall: u64,
    /// Pipeline fill / quantizer latency per pass.
    pub overhead: u64,
    /// Total multiplication OPs executed (utilization denominator).
    pub mul_ops: u64,
}

impl CycleCost {
    pub fn total(&self) -> u64 {
        self.compute + self.input_stall + self.writeback_stall + self.overhead
    }

    /// MAC-array utilization: achieved OPs over peak OPs for the elapsed
    /// cycles (peak = 4096 MACs x ops-per-cycle-per-MAC).
    pub fn utilization(&self, mode: Mode) -> f64 {
        let peak_per_cycle =
            (GRID_ROWS * GRID_COLS * 64 * mode.pairs_per_cycle()) as f64;
        self.mul_ops as f64 / (self.total() as f64 * peak_per_cycle)
    }

    pub fn add(&mut self, o: &CycleCost) {
        self.compute += o.compute;
        self.input_stall += o.input_stall;
        self.writeback_stall += o.writeback_stall;
        self.overhead += o.overhead;
        self.mul_ops += o.mul_ops;
    }

    /// Wall-clock at a given frequency.
    pub fn micros(&self, freq_mhz: f64) -> f64 {
        self.total() as f64 / freq_mhz
    }
}

/// Bits of one quantized 8x8 input tile (elements + shared exponent).
pub fn tile_bits(fmt: ElementFormat) -> u64 {
    64 * fmt.bits() as u64 + 8
}

/// Per-pass pipeline overhead: PE-grid pipeline fill + quantizer latency.
/// (Calibrated against the paper's Table IV absolute latencies; the
/// *ratios* between precision modes come out of the schedule itself.)
pub const PASS_OVERHEAD_CYCLES: u64 = 4;

/// Schedule one GeMM `[m, k] x [k, n]` and return its cycle cost.
///
/// The `stage` determines the writeback path (paper §IV-B): forward and
/// backward outputs pass through the quantizer and are written back at
/// element width, absorbed by spare input bandwidth where possible;
/// weight-gradient outputs leave as **FP32** for the weight-update
/// accelerator, and the array stalls while they drain ("during stall
/// cycles this bandwidth is dedicated to writing back the FP32 outputs").
pub fn gemm_cycles_staged(m: usize, k: usize, n: usize, fmt: ElementFormat, stage: Stage) -> CycleCost {
    let mode = fmt.mac_mode();
    let step_cycles = mode.cycles_per_block() as u64;
    let mb = m.div_ceil(SQ);
    let kb = k.div_ceil(SQ).max(1);
    let nb = n.div_ceil(SQ);
    let passes_m = mb.div_ceil(GRID_ROWS) as u64;
    let passes_n = nb.div_ceil(GRID_COLS) as u64;
    let passes = passes_m * passes_n;

    // per block-step operand traffic: one tile per grid row + per column
    let step_bits = (GRID_ROWS as u64 + GRID_COLS as u64) * tile_bits(fmt);
    let step_budget = BW_BITS_PER_CYCLE * step_cycles;
    let input_stall_per_step = step_bits.saturating_sub(step_budget).div_ceil(BW_BITS_PER_CYCLE);

    let compute_per_pass = kb as u64 * step_cycles;
    let wb_stall_per_pass = match stage {
        Stage::Forward | Stage::Backward => {
            // quantized writeback (64 tiles at element width) rides the
            // spare input bandwidth accumulated over the pass
            let wb_bits = (GRID_ROWS * GRID_COLS) as u64 * tile_bits(fmt);
            let spare = (step_budget + input_stall_per_step * BW_BITS_PER_CYCLE)
                .saturating_sub(step_bits)
                * kb as u64;
            wb_bits.saturating_sub(spare).div_ceil(BW_BITS_PER_CYCLE)
        }
        Stage::WeightGrad => {
            // FP32 writeback serializes: the array stalls while 64 tiles
            // x 64 x 32 bits drain at the full interface rate
            let wb_bits = (GRID_ROWS * GRID_COLS) as u64 * 64 * 32;
            wb_bits.div_ceil(BW_BITS_PER_CYCLE)
        }
    };

    // actual MACs performed (edge tiles still occupy the full grid slot)
    let mul_ops = (mb * SQ * nb * SQ * kb * SQ) as u64;

    CycleCost {
        compute: passes * compute_per_pass,
        input_stall: passes * input_stall_per_step * kb as u64,
        writeback_stall: passes * wb_stall_per_pass,
        overhead: passes * PASS_OVERHEAD_CYCLES,
        mul_ops,
    }
}

/// Forward-stage GeMM schedule (the common default).
pub fn gemm_cycles(m: usize, k: usize, n: usize, fmt: ElementFormat) -> CycleCost {
    gemm_cycles_staged(m, k, n, fmt, Stage::Forward)
}

/// The three GeMMs of one dense layer in one training step
/// (fwd `X@W`, bwd `E@Wt`, wgrad `Xt@E`), paper Fig. 5.
pub fn layer_train_cycles(batch: usize, din: usize, dout: usize, fmt: ElementFormat) -> [CycleCost; 3] {
    [
        gemm_cycles_staged(batch, din, dout, fmt, Stage::Forward),
        gemm_cycles_staged(batch, dout, din, fmt, Stage::Backward),
        gemm_cycles_staged(din, batch, dout, fmt, Stage::WeightGrad),
    ]
}

/// Full training-step cost for an MLP given its layer dims.
pub fn train_step_cycles(batch: usize, dims: &[usize], fmt: ElementFormat) -> CycleCost {
    let mut total = CycleCost::default();
    for w in dims.windows(2) {
        let [fwd, bwd, wg] = layer_train_cycles(batch, w[0], w[1], fmt);
        total.add(&fwd);
        total.add(&bwd);
        total.add(&wg);
    }
    total
}

/// Inference-only (forward) cost.
pub fn forward_cycles(batch: usize, dims: &[usize], fmt: ElementFormat) -> CycleCost {
    let mut total = CycleCost::default();
    for w in dims.windows(2) {
        total.add(&gemm_cycles(batch, w[0], w[1], fmt));
    }
    total
}

/// The pusher workload MLP from the paper's §V-C: 4 fully-connected
/// layers, in/out 32, hidden 256.
pub const PUSHER_DIMS: [usize; 5] = [32, 256, 256, 256, 32];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp4_saturates_interface_exactly() {
        // 20 tiles x (64*4 + 8) = 5280 bits in 1 cycle — the paper's
        // headline bandwidth number.
        assert_eq!(20 * tile_bits(ElementFormat::E2M1), BW_BITS_PER_CYCLE);
    }

    #[test]
    fn fp8_fits_two_cycle_budget() {
        let bits = 20 * tile_bits(ElementFormat::E4M3);
        assert!(bits <= 2 * BW_BITS_PER_CYCLE, "{bits}");
        // ... barely: >98% utilized ("fully utilized during FP8")
        assert!(bits as f64 / (2 * BW_BITS_PER_CYCLE) as f64 > 0.98);
    }

    #[test]
    fn int8_has_input_headroom() {
        let bits = 20 * tile_bits(ElementFormat::Int8);
        assert!((bits as f64) < 0.3 * (8 * BW_BITS_PER_CYCLE) as f64);
    }

    #[test]
    fn no_input_stalls_in_any_standard_mode() {
        for fmt in crate::mx::ALL_ELEMENT_FORMATS {
            let c = gemm_cycles(32, 256, 256, fmt);
            assert_eq!(c.input_stall, 0, "{fmt:?}");
        }
    }

    #[test]
    fn mode_compute_ratios() {
        let i8c = gemm_cycles(32, 256, 256, ElementFormat::Int8).compute;
        let f8c = gemm_cycles(32, 256, 256, ElementFormat::E4M3).compute;
        let f4c = gemm_cycles(32, 256, 256, ElementFormat::E2M1).compute;
        assert_eq!(i8c, 4 * f8c);
        assert_eq!(i8c, 8 * f4c);
    }

    #[test]
    fn wgrad_stalls_dominate_in_fp_modes() {
        // weight-gradient GeMM: K = batch = 32 -> 4 block-steps per pass,
        // frequent serialized FP32 writebacks dominate in FP modes.
        let wg = gemm_cycles_staged(256, 32, 256, ElementFormat::E4M3, Stage::WeightGrad);
        assert!(
            wg.writeback_stall > wg.compute,
            "wgrad writeback {} should exceed compute {}",
            wg.writeback_stall,
            wg.compute
        );
        // forward-stage outputs are quantized and mostly absorbed
        let fwd = gemm_cycles_staged(32, 256, 256, ElementFormat::Int8, Stage::Forward);
        assert!(fwd.writeback_stall < fwd.compute / 4);
    }

    #[test]
    fn utilization_patterns_match_paper_narrative() {
        // fwd/bwd high utilization, wgrad substantially reduced
        let fwd = gemm_cycles_staged(32, 256, 256, ElementFormat::Int8, Stage::Forward);
        let wg = gemm_cycles_staged(256, 32, 256, ElementFormat::Int8, Stage::WeightGrad);
        assert!(fwd.utilization(Mode::Int8) > 0.5, "{}", fwd.utilization(Mode::Int8));
        assert!(
            wg.utilization(Mode::Int8) < fwd.utilization(Mode::Int8),
            "wgrad must be lower-utilization"
        );
    }

    #[test]
    fn pusher_train_latency_ballpark_table4() {
        // Table IV: ours 10.86 / 4.82 / 3.81 us per batch-32 training
        // loop for INT8 / FP8-FP6 / FP4. The schedule must land in-band
        // (+-35%) and preserve the ordering and rough ratios.
        let t8 = train_step_cycles(32, &PUSHER_DIMS, ElementFormat::Int8).micros(500.0);
        let tf8 = train_step_cycles(32, &PUSHER_DIMS, ElementFormat::E4M3).micros(500.0);
        let tf4 = train_step_cycles(32, &PUSHER_DIMS, ElementFormat::E2M1).micros(500.0);
        assert!((t8 - 10.86).abs() / 10.86 < 0.35, "INT8 {t8} vs 10.86");
        assert!((tf8 - 4.82).abs() / 4.82 < 0.35, "FP8 {tf8} vs 4.82");
        assert!((tf4 - 3.81).abs() / 3.81 < 0.35, "FP4 {tf4} vs 3.81");
        assert!(t8 > tf8 && tf8 > tf4);
    }

    #[test]
    fn cost_totals_are_consistent() {
        let c = gemm_cycles(64, 64, 64, ElementFormat::E4M3);
        assert_eq!(c.total(), c.compute + c.input_stall + c.writeback_stall + c.overhead);
        assert_eq!(c.mul_ops, 64 * 64 * 64);
    }
}
