//! The output quantizer unit (paper Fig. 6, right).
//!
//! FP32 partial results written back from the PE grid pass through this
//! unit to be re-encoded as square MX blocks before they re-enter memory
//! (activations feeding the next layer, or errors feeding backprop).
//! Event counts (max-scan + encode per element) feed the energy model.

#![forbid(unsafe_code)]

use crate::mx::element::ElementFormat;
use crate::mx::tensor::{Layout, MxTensor};
use crate::util::mat::Mat;

/// Quantizer event counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct QuantEvents {
    /// Per-element max-magnitude scan compares.
    pub max_scans: u64,
    /// Per-element encodes (round + pack).
    pub encodes: u64,
    /// Blocks produced (one shared-exponent derivation each).
    pub blocks: u64,
}

impl QuantEvents {
    /// Accumulate another counter set (every field — keep this in sync
    /// when adding counters, like [`crate::arith::Events::add`]).
    pub fn add(&mut self, o: &QuantEvents) {
        self.max_scans += o.max_scans;
        self.encodes += o.encodes;
        self.blocks += o.blocks;
    }
}

/// The requantization unit.
#[derive(Debug, Default)]
pub struct Quantizer {
    pub events: QuantEvents,
}

impl Quantizer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quantize an FP32 result matrix into square MX blocks.
    pub fn quantize(&mut self, m: &Mat, fmt: ElementFormat) -> MxTensor {
        let t = MxTensor::quantize(m, fmt, Layout::Square8x8);
        let n_elems = (t.brows * t.bcols * 64) as u64;
        self.events.max_scans += n_elems;
        self.events.encodes += n_elems;
        self.events.blocks += t.blocks.len() as u64;
        t
    }

    /// Cycles to quantize one 4x16-tile writeback burst: the unit is
    /// pipelined one block per cycle (64 parallel encoders).
    pub fn burst_cycles(&self) -> u64 {
        64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn quantize_counts_events() {
        let mut rng = Pcg64::new(1);
        let m = Mat::randn(16, 16, 1.0, &mut rng);
        let mut q = Quantizer::new();
        let t = q.quantize(&m, ElementFormat::E4M3);
        assert_eq!(t.blocks.len(), 4);
        assert_eq!(q.events.blocks, 4);
        assert_eq!(q.events.encodes, 256);
    }

    #[test]
    fn quantize_roundtrip_reasonable() {
        let mut rng = Pcg64::new(2);
        let m = Mat::randn(32, 32, 1.0, &mut rng);
        let mut q = Quantizer::new();
        let t = q.quantize(&m, ElementFormat::Int8);
        assert!(t.dequantize().mse(&m) < 1e-3);
    }
}
