//! The learning-enabled MX GeMM core (paper §IV-B, Fig. 6).
//!
//! A 4x16 grid of square-block PE arrays (4096 MACs total), output-
//! stationary, fed through a 5280 bit/cycle memory interface (~330 GB/s
//! at 500 MHz). The grid height of 4 matches a batch of 32 (32/8 square
//! rows); the width of 16 balances bandwidth and parallelism.
//!
//! * [`core::GemmCore`] — functional GeMM + cycle/event accounting.
//! * [`schedule`] — the cycle-accurate pass schedule: per-GeMM latency
//!   with input-bandwidth stalls and FP32 writeback stalls (the wgrad
//!   utilization collapse the paper describes), plus whole-training-step
//!   costing for MLP workloads.
//! * [`quantizer::Quantizer`] — the output requantization unit.
//! * [`memory`] — on-chip footprint accounting (regenerates Table III).

pub mod core;
pub mod memory;
pub mod quantizer;
pub mod schedule;

pub use self::core::GemmCore;
pub use memory::{footprint_dacapo, footprint_fp32, footprint_ours, MlpShape};
pub use schedule::{gemm_cycles, train_step_cycles, CycleCost, Stage};

/// Grid geometry and interface width (paper §IV-B).
pub const GRID_ROWS: usize = 4;
pub const GRID_COLS: usize = 16;
/// Peak memory bandwidth in bits per cycle (~330 GB/s @ 500 MHz).
pub const BW_BITS_PER_CYCLE: u64 = 5280;
/// Total MACs (iso-peak-throughput comparison point with Dacapo).
pub const TOTAL_MACS: usize = GRID_ROWS * GRID_COLS * 64;
