//! A minimal Rust lexer for `mxlint`.
//!
//! This is not a general-purpose Rust front end: it produces exactly the
//! token stream the lint rules in [`crate::lint::rules`] need — idents,
//! number/string/char literals, lifetimes, and single-character
//! punctuation — while stripping comments (but recording the lines of
//! `SAFETY:` comments for rule L7). The token *text* is preserved
//! verbatim so rule L5 can hash a function body as a whitespace- and
//! comment-insensitive fingerprint.
//!
//! The lexer is intentionally simple and deterministic: it operates on
//! bytes, treats every punctuation byte as its own token (`::` is two
//! `:` tokens), and never errors — unexpected bytes become `Punct`
//! tokens. `ci/mxlint_mirror.py` ports this file byte-for-byte so the
//! committed `lint.manifest` can be regenerated without a Rust
//! toolchain; keep the two in lockstep.

#![forbid(unsafe_code)]

/// Kind of a lexed token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `unsafe`, `par_map`, ...).
    Ident,
    /// Integer literal (`8`, `0xFF`, `64usize`).
    Int,
    /// Float literal (`1.5`, `1e-3`, `2.0f32`).
    Float,
    /// String literal, including raw and byte strings.
    Str,
    /// Char or byte-char literal (`'a'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// Single punctuation byte (`{`, `.`, `:`, ...).
    Punct,
}

/// One token: kind, verbatim text, and 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// Result of lexing one file.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    /// Lines (1-based) of comments containing `SAFETY:`.
    pub safety_lines: Vec<u32>,
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_' || b >= 0x80
}

fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_' || b >= 0x80
}

fn starts_with_radix(text: &[u8]) -> bool {
    text.len() >= 2
        && text[0] == b'0'
        && matches!(text[1], b'x' | b'X' | b'b' | b'B' | b'o' | b'O')
}

/// Classify a lexed number body as `Int` or `Float`.
///
/// Rust-specific wrinkle: integer suffixes contain letters (`8usize`
/// contains an `e`), so suffix stripping must run before the
/// exponent-letter check.
fn classify_number(text: &str) -> TokKind {
    let b = text.as_bytes();
    if starts_with_radix(b) {
        return TokKind::Int;
    }
    if text.contains('.') {
        return TokKind::Float;
    }
    const INT_SUFFIXES: [&str; 12] = [
        "usize", "isize", "u128", "i128", "u64", "i64", "u32", "i32", "u16", "i16", "u8", "i8",
    ];
    for suf in INT_SUFFIXES {
        if let Some(core) = text.strip_suffix(suf) {
            if !core.is_empty() && core.bytes().all(|c| c.is_ascii_digit() || c == b'_') {
                return TokKind::Int;
            }
        }
    }
    if text.ends_with("f32") || text.ends_with("f64") {
        return TokKind::Float;
    }
    if text.contains('e') || text.contains('E') {
        return TokKind::Float;
    }
    TokKind::Int
}

/// Lex `src` into tokens plus `SAFETY:` comment lines.
pub fn lex(src: &[u8]) -> Lexed {
    let b = src;
    let n = b.len();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut out = Lexed::default();

    let push = |out: &mut Lexed, kind: TokKind, text: &[u8], line: u32| {
        out.toks.push(Tok { kind, text: String::from_utf8_lossy(text).into_owned(), line });
    };

    while i < n {
        let c = b[i];
        // -------- whitespace
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        // -------- comments
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let start = i;
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            if contains_safety(&b[start..i]) {
                out.safety_lines.push(line);
            }
            continue;
        }
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            if contains_safety(&b[start..i]) {
                out.safety_lines.push(start_line);
            }
            continue;
        }
        // -------- raw strings: r"..." / r#"..."# (and br variants below)
        if c == b'r' && i + 1 < n && (b[i + 1] == b'"' || b[i + 1] == b'#') {
            if let Some((end, nl)) = scan_raw_string(b, i + 1) {
                push(&mut out, TokKind::Str, &b[i..end], line);
                line += nl;
                i = end;
                continue;
            }
            // `r#foo` raw identifier or stray `r#`: fall through to ident.
        }
        // -------- byte strings / byte chars
        if c == b'b' && i + 1 < n {
            if b[i + 1] == b'"' {
                let (end, nl) = scan_string(b, i + 2);
                push(&mut out, TokKind::Str, &b[i..end], line);
                line += nl;
                i = end;
                continue;
            }
            if b[i + 1] == b'\'' {
                let (end, kind) = scan_char_or_lifetime(b, i + 2);
                push(&mut out, kind, &b[i..end], line);
                i = end;
                continue;
            }
            if b[i + 1] == b'r' && i + 2 < n && (b[i + 2] == b'"' || b[i + 2] == b'#') {
                if let Some((end, nl)) = scan_raw_string(b, i + 2) {
                    push(&mut out, TokKind::Str, &b[i..end], line);
                    line += nl;
                    i = end;
                    continue;
                }
            }
        }
        // -------- plain strings
        if c == b'"' {
            let (end, nl) = scan_string(b, i + 1);
            push(&mut out, TokKind::Str, &b[i..end], line);
            line += nl;
            i = end;
            continue;
        }
        // -------- char literal vs lifetime
        if c == b'\'' {
            let (end, kind) = scan_char_or_lifetime(b, i + 1);
            push(&mut out, kind, &b[i..end], line);
            i = end;
            continue;
        }
        // -------- identifiers / keywords
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_cont(b[i]) {
                i += 1;
            }
            push(&mut out, TokKind::Ident, &b[start..i], line);
            continue;
        }
        // -------- numbers
        if c.is_ascii_digit() {
            let start = i;
            let mut has_dot = false;
            i += 1;
            while i < n {
                let d = b[i];
                if d.is_ascii_alphanumeric() || d == b'_' {
                    i += 1;
                    continue;
                }
                if d == b'.'
                    && !has_dot
                    && i + 1 < n
                    && b[i + 1].is_ascii_digit()
                {
                    has_dot = true;
                    i += 1;
                    continue;
                }
                if (d == b'+' || d == b'-')
                    && matches!(b[i - 1], b'e' | b'E')
                    && !starts_with_radix(&b[start..i])
                    && i + 1 < n
                    && b[i + 1].is_ascii_digit()
                {
                    i += 1;
                    continue;
                }
                break;
            }
            let text = &b[start..i];
            let kind = classify_number(&String::from_utf8_lossy(text));
            push(&mut out, kind, text, line);
            continue;
        }
        // -------- punctuation (single byte)
        push(&mut out, TokKind::Punct, &b[i..i + 1], line);
        i += 1;
    }
    out
}

fn contains_safety(bytes: &[u8]) -> bool {
    bytes.windows(7).any(|w| w == b"SAFETY:")
}

/// Scan a non-raw string body starting just after the opening quote.
/// Returns (index just past closing quote, newline count inside).
fn scan_string(b: &[u8], mut i: usize) -> (usize, u32) {
    let n = b.len();
    let mut nl = 0u32;
    while i < n {
        match b[i] {
            b'\\' => i += 2,
            b'"' => return (i + 1, nl),
            b'\n' => {
                nl += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    (n, nl)
}

/// Scan a raw string starting at the `#`s-or-quote position (just after
/// the `r`). Returns `Some((index past closing delimiter, newlines))`
/// or `None` if this is not actually a raw string (`r#ident`).
fn scan_raw_string(b: &[u8], mut i: usize) -> Option<(usize, u32)> {
    let n = b.len();
    let mut hashes = 0usize;
    while i < n && b[i] == b'#' {
        hashes += 1;
        i += 1;
    }
    if i >= n || b[i] != b'"' {
        return None;
    }
    i += 1;
    let mut nl = 0u32;
    while i < n {
        if b[i] == b'\n' {
            nl += 1;
            i += 1;
            continue;
        }
        if b[i] == b'"' {
            let mut j = i + 1;
            let mut h = 0usize;
            while j < n && h < hashes && b[j] == b'#' {
                h += 1;
                j += 1;
            }
            if h == hashes {
                return Some((j, nl));
            }
        }
        i += 1;
    }
    Some((n, nl))
}

/// Disambiguate `'a'` (char) from `'a` (lifetime), starting just after
/// the opening quote. Returns (index past token, kind).
fn scan_char_or_lifetime(b: &[u8], i: usize) -> (usize, TokKind) {
    let n = b.len();
    if i >= n {
        return (n, TokKind::Char);
    }
    if b[i] == b'\\' {
        // escape: '\n', '\u{1F600}', '\'', ...
        let mut j = i + 1;
        if j < n {
            let esc = b[j];
            j += 1;
            if esc == b'u' && j < n && b[j] == b'{' {
                while j < n && b[j] != b'}' {
                    j += 1;
                }
                j += 1;
            }
        }
        if j < n && b[j] == b'\'' {
            j += 1;
        }
        return (j, TokKind::Char);
    }
    if is_ident_start(b[i]) {
        let mut j = i;
        while j < n && is_ident_cont(b[j]) {
            j += 1;
        }
        if j < n && b[j] == b'\'' {
            return (j + 1, TokKind::Char);
        }
        return (j, TokKind::Lifetime);
    }
    // non-ident char like ' ', '0' handled above (digits are ident_cont
    // but not ident_start), '"', '.' ...
    let mut j = i + 1;
    while j < n && b[j] != b'\'' && b[j] != b'\n' {
        j += 1;
    }
    if j < n && b[j] == b'\'' {
        j += 1;
    }
    (j, TokKind::Char)
}

/// FNV-1a 64-bit over each token's text bytes with a `\n` separator —
/// the whitespace/comment-insensitive body fingerprint rule L5 records
/// in `lint.manifest`.
pub fn token_hash(toks: &[Tok]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    for t in toks {
        for &byte in t.text.as_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(PRIME);
        }
        h ^= b'\n' as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src.as_bytes()).toks.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_and_puncts() {
        let toks = kinds("fn foo(x: u8) -> u8 { x }");
        assert_eq!(toks[0], (TokKind::Ident, "fn".into()));
        assert_eq!(toks[1], (TokKind::Ident, "foo".into()));
        assert_eq!(toks[2], (TokKind::Punct, "(".into()));
    }

    #[test]
    fn double_colon_is_two_tokens() {
        let toks = kinds("a::b");
        assert_eq!(toks.len(), 4);
        assert_eq!(toks[1], (TokKind::Punct, ":".into()));
        assert_eq!(toks[2], (TokKind::Punct, ":".into()));
    }

    #[test]
    fn number_classification() {
        assert_eq!(classify_number("8"), TokKind::Int);
        assert_eq!(classify_number("8usize"), TokKind::Int);
        assert_eq!(classify_number("0xFF"), TokKind::Int);
        assert_eq!(classify_number("0x1b3"), TokKind::Int);
        assert_eq!(classify_number("1e-3"), TokKind::Float);
        assert_eq!(classify_number("2.0"), TokKind::Float);
        assert_eq!(classify_number("1f32"), TokKind::Float);
        assert_eq!(classify_number("123i64"), TokKind::Int);
    }

    #[test]
    fn exponent_sign_is_absorbed() {
        let toks = kinds("let x = 1e-3;");
        assert!(toks.iter().any(|t| t.1 == "1e-3" && t.0 == TokKind::Float));
    }

    #[test]
    fn range_dots_not_absorbed() {
        let toks = kinds("for i in 0..8 {}");
        assert!(toks.iter().any(|t| t.1 == "0" && t.0 == TokKind::Int));
        assert!(toks.iter().any(|t| t.1 == "8" && t.0 == TokKind::Int));
        assert_eq!(toks.iter().filter(|t| t.1 == "." && t.0 == TokKind::Punct).count(), 2);
    }

    #[test]
    fn char_vs_lifetime() {
        let toks = kinds("let c = 'a'; fn f<'a>(x: &'a u8) {} let s = ' ';");
        assert!(toks.iter().any(|t| t.1 == "'a'" && t.0 == TokKind::Char));
        assert!(toks.iter().any(|t| t.1 == "'a" && t.0 == TokKind::Lifetime));
        assert!(toks.iter().any(|t| t.1 == "' '" && t.0 == TokKind::Char));
    }

    #[test]
    fn strings_and_raw_strings() {
        let toks = kinds(r##"let a = "hi \" there"; let b = r#"raw "quoted""#;"##);
        assert_eq!(toks.iter().filter(|t| t.0 == TokKind::Str).count(), 2);
    }

    #[test]
    fn comments_stripped_and_safety_recorded() {
        let lexed = lex(b"// SAFETY: fine\nlet x = 1; /* SAFETY: also */\n");
        assert_eq!(lexed.safety_lines, vec![1, 2]);
        assert!(lexed.toks.iter().all(|t| !t.text.contains("SAFETY")));
    }

    #[test]
    fn lines_tracked_across_strings() {
        let lexed = lex(b"let a = \"x\ny\";\nlet b = 1;");
        let b_tok = lexed.toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b_tok.line, 3);
    }

    #[test]
    fn token_hash_ignores_whitespace_and_comments() {
        let a = lex(b"fn f() { x + 1 }");
        let b = lex(b"fn f()   { // comment\n  x + 1 }");
        assert_eq!(token_hash(&a.toks), token_hash(&b.toks));
        let c = lex(b"fn f() { x + 2 }");
        assert_ne!(token_hash(&a.toks), token_hash(&c.toks));
    }
}
