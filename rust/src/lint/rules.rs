//! The mxlint rule engine: invariant checks L1–L9 over lexed sources.
//!
//! Each rule is a pure function from token streams to [`Finding`]s, so
//! the fixture tests in `rust/tests/lint.rs` can drive them with
//! in-memory snippets and the self-run test can drive them with the
//! real tree. DESIGN.md §9 is the human-readable catalog; the rule
//! constants here are the machine-readable one. `ci/mxlint_mirror.py`
//! ports this file byte-for-byte — keep them in lockstep.

#![forbid(unsafe_code)]

use std::collections::{BTreeMap, BTreeSet};

use super::lex::{token_hash, Lexed, Tok, TokKind};

/// One lexed source file with its repo-relative, `/`-separated path
/// (e.g. `rust/src/mx/packed.rs`).
pub struct SourceFile {
    pub rel: String,
    pub lexed: Lexed,
}

/// One rule violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub message: String,
}

/// Per-rule allowlist: rule name -> (key, reason) entries from lint.toml.
pub type Allow = BTreeMap<String, Vec<(String, String)>>;

pub(crate) fn allowed(allow: &Allow, rule: &str, key: &str) -> bool {
    allow.get(rule).is_some_and(|v| v.iter().any(|(k, _)| k == key))
}

fn is_punct(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Punct && t.text == s
}

fn is_ident(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

/// Path under `rust/src/`, or `None` for files outside it.
pub(crate) fn under_src(rel: &str) -> Option<&str> {
    rel.strip_prefix("rust/src/")
}

/// Index of the `}` matching the `{` at `open`, or `toks.len()` if the
/// stream is unbalanced.
pub(crate) fn brace_match(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < toks.len() {
        if is_punct(&toks[i], "{") {
            depth += 1;
        } else if is_punct(&toks[i], "}") {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    toks.len()
}

/// A discovered `fn` item.
pub(crate) struct FnInfo {
    pub name: String,
    pub is_pub: bool,
    pub line: u32,
    /// Token index of the `fn` keyword.
    pub kw: usize,
    /// `(open_brace_idx, close_brace_idx)`; `None` for bodyless decls.
    pub body: Option<(usize, usize)>,
}

/// Discover every `fn` item (including nested ones) in a token stream.
pub(crate) fn functions(toks: &[Tok]) -> Vec<FnInfo> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 1 < toks.len() {
        if is_ident(&toks[i], "fn") && toks[i + 1].kind == TokKind::Ident {
            let name = toks[i + 1].text.clone();
            let mut is_pub = false;
            for j in (i.saturating_sub(6)..i).rev() {
                if is_punct(&toks[j], ";") || is_punct(&toks[j], "}") || is_punct(&toks[j], "{") {
                    break;
                }
                if is_ident(&toks[j], "pub") {
                    is_pub = true;
                    break;
                }
            }
            // Find the body `{`, tracking paren/bracket depth so a `;`
            // inside an array type (`&mut [u64; 8]`) does not read as a
            // bodyless declaration.
            let mut depth = 0i32;
            let mut j = i + 2;
            let mut body = None;
            while j < toks.len() {
                let t = &toks[j];
                if t.kind == TokKind::Punct {
                    match t.text.as_str() {
                        "(" | "[" => depth += 1,
                        ")" | "]" => depth -= 1,
                        "{" if depth == 0 => {
                            body = Some((j, brace_match(toks, j)));
                            break;
                        }
                        ";" if depth == 0 => break,
                        _ => {}
                    }
                }
                j += 1;
            }
            out.push(FnInfo { name, is_pub, line: toks[i + 1].line, kw: i, body });
            i += 2;
        } else {
            i += 1;
        }
    }
    out
}

/// Token-index ranges covered by `#[cfg(test)]` items or `#[test]` fns.
pub(crate) fn test_regions(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        let cfg_test = i + 6 < toks.len()
            && is_punct(&toks[i], "#")
            && is_punct(&toks[i + 1], "[")
            && is_ident(&toks[i + 2], "cfg")
            && is_punct(&toks[i + 3], "(")
            && is_ident(&toks[i + 4], "test")
            && is_punct(&toks[i + 5], ")")
            && is_punct(&toks[i + 6], "]");
        let test_attr = i + 3 < toks.len()
            && is_punct(&toks[i], "#")
            && is_punct(&toks[i + 1], "[")
            && is_ident(&toks[i + 2], "test")
            && is_punct(&toks[i + 3], "]");
        if cfg_test || test_attr {
            let after = if cfg_test { i + 7 } else { i + 4 };
            for j in after..(after + 40).min(toks.len()) {
                if is_punct(&toks[j], ";") {
                    break; // `#[cfg(test)] use ...;` — no region
                }
                if is_punct(&toks[j], "{") {
                    out.push((i, brace_match(toks, j)));
                    break;
                }
            }
        }
        i += 1;
    }
    out
}

/// Token-index ranges of `const`/`static` items (scheme-constant
/// tables), including inline `const { ... }` blocks. `const fn` items
/// are *not* const regions.
pub(crate) fn const_regions(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if (is_ident(&toks[i], "const") || is_ident(&toks[i], "static"))
            && !(i + 1 < toks.len() && is_ident(&toks[i + 1], "fn"))
        {
            if i + 1 < toks.len() && is_punct(&toks[i + 1], "{") {
                let close = brace_match(toks, i + 1);
                out.push((i, close));
                i = close + 1;
                continue;
            }
            let mut depth = 0i32;
            let mut j = i + 1;
            while j < toks.len() {
                let t = &toks[j];
                if t.kind == TokKind::Punct {
                    match t.text.as_str() {
                        "(" | "[" | "{" => depth += 1,
                        ")" | "]" | "}" => depth -= 1,
                        ";" if depth <= 0 => break,
                        _ => {}
                    }
                }
                j += 1;
            }
            out.push((i, j));
            i = j + 1;
            continue;
        }
        i += 1;
    }
    out
}

pub(crate) fn in_regions(regions: &[(usize, usize)], idx: usize) -> bool {
    regions.iter().any(|&(a, b)| idx >= a && idx <= b)
}

// ------------------------------------------------------------------ L1

const L1_FILES: [&str; 5] = [
    "rust/src/util/par.rs",
    "rust/src/util/mat.rs",
    "rust/src/mx/tensor.rs",
    "rust/src/pearray/array.rs",
    "rust/src/gemmcore/core.rs",
];
const L1_PAR_IDENTS: [&str; 3] = ["par_map", "par_chunks_mut", "spawn"];

/// L1: every parallel kernel in the scoped files has a `_serial` twin,
/// and every public `_serial` twin is exercised by `rust/tests/`.
pub fn l1(src: &[SourceFile], tests: &[SourceFile], allow: &Allow) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut test_idents: BTreeSet<&str> = BTreeSet::new();
    for t in tests {
        for tok in &t.lexed.toks {
            if tok.kind == TokKind::Ident {
                test_idents.insert(tok.text.as_str());
            }
        }
    }
    for f in src.iter().filter(|f| L1_FILES.contains(&f.rel.as_str())) {
        let toks = &f.lexed.toks;
        let fns = functions(toks);
        let tregions = test_regions(toks);
        let names: BTreeSet<&str> = fns.iter().map(|fi| fi.name.as_str()).collect();
        for fi in &fns {
            if !fi.is_pub || in_regions(&tregions, fi.kw) {
                continue;
            }
            let Some((open, close)) = fi.body else { continue };
            if fi.name.ends_with("_serial") {
                if !test_idents.contains(fi.name.as_str()) && !allowed(allow, "L1", &fi.name) {
                    out.push(Finding {
                        rule: "L1",
                        file: f.rel.clone(),
                        line: fi.line,
                        message: format!(
                            "serial twin `{}` is not referenced from any identity test in rust/tests/",
                            fi.name
                        ),
                    });
                }
                continue;
            }
            let has_par = toks[open + 1..close.min(toks.len())]
                .iter()
                .any(|t| t.kind == TokKind::Ident && L1_PAR_IDENTS.contains(&t.text.as_str()));
            if !has_par || allowed(allow, "L1", &fi.name) {
                continue;
            }
            let twin = format!("{}_serial", fi.name);
            if !names.contains(twin.as_str()) {
                out.push(Finding {
                    rule: "L1",
                    file: f.rel.clone(),
                    line: fi.line,
                    message: format!("parallel kernel `{}` has no `{twin}` twin", fi.name),
                });
            }
        }
    }
    out
}

// ------------------------------------------------------------------ L2

const L2_BANNED: [&str; 3] = ["log2", "ln", "powf"];

/// L2: no float `log2(`/`ln(`/`powf(` under `rust/src/mx/` — shared
/// exponents must come from `element::floor_log2` (exact on the f64
/// exponent field; PR 1 fixed the `log2().floor()` misround).
pub fn l2(src: &[SourceFile], allow: &Allow) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in src.iter().filter(|f| f.rel.starts_with("rust/src/mx/")) {
        let toks = &f.lexed.toks;
        let tregions = test_regions(toks);
        for i in 0..toks.len().saturating_sub(1) {
            if toks[i].kind == TokKind::Ident
                && L2_BANNED.contains(&toks[i].text.as_str())
                && is_punct(&toks[i + 1], "(")
                && !in_regions(&tregions, i)
                && !allowed(allow, "L2", under_src(&f.rel).unwrap_or(&f.rel))
            {
                out.push(Finding {
                    rule: "L2",
                    file: f.rel.clone(),
                    line: toks[i].line,
                    message: format!(
                        "`{}(` in MX exponent code — use element::floor_log2 instead",
                        toks[i].text
                    ),
                });
            }
        }
    }
    out
}

// ------------------------------------------------------------------ L3

/// Parse an integer literal's value plus its hex-digit count (0 for
/// non-hex literals).
fn int_value(text: &str) -> Option<(u128, usize)> {
    let mut t = text.replace('_', "");
    const INT_SUFFIXES: [&str; 12] = [
        "usize", "isize", "u128", "i128", "u64", "i64", "u32", "i32", "u16", "i16", "u8", "i8",
    ];
    for suf in INT_SUFFIXES {
        if let Some(core) = t.strip_suffix(suf) {
            if !core.is_empty() {
                t = core.to_string();
                break;
            }
        }
    }
    if let Some(hex) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        return u128::from_str_radix(hex, 16).ok().map(|v| (v, hex.len()));
    }
    if let Some(bin) = t.strip_prefix("0b").or_else(|| t.strip_prefix("0B")) {
        return u128::from_str_radix(bin, 2).ok().map(|v| (v, 0));
    }
    if let Some(oct) = t.strip_prefix("0o").or_else(|| t.strip_prefix("0O")) {
        return u128::from_str_radix(oct, 8).ok().map(|v| (v, 0));
    }
    t.parse::<u128>().ok().map(|v| (v, 0))
}

/// L3: no magic bit-width literals (4/6/8, or >=8-hex-digit lane masks)
/// in `mx/packed.rs` outside const tables, tests, and allowlisted fns.
pub fn l3(src: &[SourceFile], allow: &Allow) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in src.iter().filter(|f| f.rel == "rust/src/mx/packed.rs") {
        let toks = &f.lexed.toks;
        let fns = functions(toks);
        let tregions = test_regions(toks);
        let cregions = const_regions(toks);
        for (i, t) in toks.iter().enumerate() {
            if t.kind != TokKind::Int || in_regions(&tregions, i) || in_regions(&cregions, i) {
                continue;
            }
            let Some((v, hex_digits)) = int_value(&t.text) else { continue };
            let magic = matches!(v, 4 | 6 | 8) || hex_digits >= 8;
            if !magic {
                continue;
            }
            let in_allowed_fn = fns.iter().any(|fi| {
                let end = fi.body.map(|(_, c)| c).unwrap_or(fi.kw);
                i >= fi.kw && i <= end && allowed(allow, "L3", &fi.name)
            });
            if in_allowed_fn {
                continue;
            }
            out.push(Finding {
                rule: "L3",
                file: f.rel.clone(),
                line: t.line,
                message: format!(
                    "magic bit-width literal `{}` outside a scheme-constant table — \
                     derive from ElementFormat::bits()/scheme constants",
                    t.text
                ),
            });
        }
    }
    out
}

// ------------------------------------------------------------------ L4

const L4_DIRS: [&str; 6] = [
    "rust/src/fleet/",
    "rust/src/trainer/",
    "rust/src/backend/",
    "rust/src/coordinator/",
    "rust/src/serve/",
    "rust/src/store/",
];

/// L4: `.unwrap()`/`.expect(` banned in library code under the training
/// stack — errors propagate as structured `TrainError`.
pub fn l4(src: &[SourceFile], allow: &Allow) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in src.iter().filter(|f| L4_DIRS.iter().any(|d| f.rel.starts_with(d))) {
        let key = under_src(&f.rel).unwrap_or(&f.rel).to_string();
        if allowed(allow, "L4", &key) {
            continue;
        }
        let toks = &f.lexed.toks;
        let tregions = test_regions(toks);
        for i in 1..toks.len().saturating_sub(1) {
            if toks[i].kind == TokKind::Ident
                && (toks[i].text == "unwrap" || toks[i].text == "expect")
                && is_punct(&toks[i - 1], ".")
                && is_punct(&toks[i + 1], "(")
                && !in_regions(&tregions, i)
            {
                out.push(Finding {
                    rule: "L4",
                    file: f.rel.clone(),
                    line: toks[i].line,
                    message: format!(
                        "`.{}(` in library code — propagate a structured TrainError instead",
                        toks[i].text
                    ),
                });
            }
        }
    }
    out
}

// ------------------------------------------------------------------ L5

const L5_NAMES: [&str; 4] = ["write_bytes", "read_bytes", "to_bytes", "from_bytes"];

/// The committed byte-layout manifest (`rust/lint.manifest`).
#[derive(Debug, Default, Clone)]
pub struct Manifest {
    pub version: u32,
    /// Store-layer format version (`store/mod.rs` `VERSION`); 0 when
    /// the manifest predates the store layer.
    pub store_version: u32,
    pub entries: Vec<(String, u64)>,
}

/// Parse `const VERSION: ... = <int>` from `trainer/checkpoint.rs`.
pub fn checkpoint_version(src: &[SourceFile]) -> u32 {
    for f in src.iter().filter(|f| f.rel == "rust/src/trainer/checkpoint.rs") {
        let toks = &f.lexed.toks;
        for i in 0..toks.len().saturating_sub(1) {
            if is_ident(&toks[i], "const") && is_ident(&toks[i + 1], "VERSION") {
                for t in &toks[i + 2..(i + 10).min(toks.len())] {
                    if t.kind == TokKind::Int {
                        if let Some((v, _)) = int_value(&t.text) {
                            return v as u32;
                        }
                    }
                }
            }
        }
    }
    0
}

/// Parse `const VERSION: ... = <int>` from `store/mod.rs` (0 when the
/// store layer is absent, so pre-store manifests stay valid).
pub fn store_version(src: &[SourceFile]) -> u32 {
    for f in src.iter().filter(|f| f.rel == "rust/src/store/mod.rs") {
        let toks = &f.lexed.toks;
        for i in 0..toks.len().saturating_sub(1) {
            if is_ident(&toks[i], "const") && is_ident(&toks[i + 1], "VERSION") {
                for t in &toks[i + 2..(i + 10).min(toks.len())] {
                    if t.kind == TokKind::Int {
                        if let Some((v, _)) = int_value(&t.text) {
                            return v as u32;
                        }
                    }
                }
            }
        }
    }
    0
}

/// Discover every byte-layout function and its body token hash, keyed
/// `path-under-src::name` (duplicate keys get `#2`, `#3`, ... suffixes).
pub fn layout_hashes(src: &[SourceFile]) -> Vec<(String, u64, u32, String)> {
    let mut seen: BTreeMap<String, usize> = BTreeMap::new();
    let mut out = Vec::new();
    for f in src.iter().filter(|f| f.rel.starts_with("rust/src/")) {
        let toks = &f.lexed.toks;
        let tregions = test_regions(toks);
        for fi in functions(toks) {
            if !L5_NAMES.contains(&fi.name.as_str()) || in_regions(&tregions, fi.kw) {
                continue;
            }
            let Some((open, close)) = fi.body else { continue };
            let base = format!("{}::{}", under_src(&f.rel).unwrap_or(&f.rel), fi.name);
            let n = seen.entry(base.clone()).or_insert(0);
            *n += 1;
            let key = if *n == 1 { base } else { format!("{base}#{n}") };
            let hash = token_hash(&toks[open + 1..close.min(toks.len())]);
            out.push((key, hash, fi.line, f.rel.clone()));
        }
    }
    out
}

/// L5: fail when a byte-layout body hash drifts from the committed
/// manifest while the governing `VERSION` constant stays put —
/// `trainer/checkpoint.rs` for checkpoint codecs, `store/mod.rs` for
/// the shard index / chunk codecs (keys under `store/`).
pub fn l5(src: &[SourceFile], manifest: &Manifest) -> Vec<Finding> {
    let mut out = Vec::new();
    let version = checkpoint_version(src);
    if version != manifest.version {
        out.push(Finding {
            rule: "L5",
            file: "rust/src/trainer/checkpoint.rs".into(),
            line: 1,
            message: format!(
                "rust/lint.manifest records VERSION {} but checkpoint.rs has VERSION {version} — \
                 run `mxlint --update-manifest` and commit the result",
                manifest.version
            ),
        });
        return out;
    }
    let sversion = store_version(src);
    if sversion != manifest.store_version {
        out.push(Finding {
            rule: "L5",
            file: "rust/src/store/mod.rs".into(),
            line: 1,
            message: format!(
                "rust/lint.manifest records store VERSION {} but store/mod.rs has \
                 VERSION {sversion} — run `mxlint --update-manifest` and commit the result",
                manifest.store_version
            ),
        });
        return out;
    }
    let current = layout_hashes(src);
    let recorded: BTreeMap<&str, u64> =
        manifest.entries.iter().map(|(k, h)| (k.as_str(), *h)).collect();
    for (key, hash, line, rel) in &current {
        match recorded.get(key.as_str()) {
            Some(&want) if want != *hash => out.push(Finding {
                rule: "L5",
                file: rel.clone(),
                line: *line,
                message: if key.starts_with("store/") {
                    format!(
                        "byte-layout of `{key}` changed ({hash:016x} != manifest {want:016x}) \
                         without a store VERSION bump (still {sversion}) — bump VERSION in \
                         store/mod.rs and run `mxlint --update-manifest`"
                    )
                } else {
                    format!(
                        "byte-layout of `{key}` changed ({hash:016x} != manifest {want:016x}) \
                         without a VERSION bump (still {version}) — bump VERSION in \
                         trainer/checkpoint.rs and run `mxlint --update-manifest`"
                    )
                },
            }),
            Some(_) => {}
            None => out.push(Finding {
                rule: "L5",
                file: rel.clone(),
                line: *line,
                message: format!(
                    "byte-layout function `{key}` has no entry in rust/lint.manifest — \
                     run `mxlint --update-manifest`"
                ),
            }),
        }
    }
    let current_keys: BTreeSet<&str> = current.iter().map(|(k, ..)| k.as_str()).collect();
    for (key, _) in &manifest.entries {
        if !current_keys.contains(key.as_str()) {
            out.push(Finding {
                rule: "L5",
                file: "rust/lint.manifest".into(),
                line: 1,
                message: format!(
                    "manifest entry `{key}` has no matching function — \
                     run `mxlint --update-manifest`"
                ),
            });
        }
    }
    out
}

// ------------------------------------------------------------------ L6

/// L6: every `results/*.json` writer (a fn calling `save_json`) must
/// stamp its doc via `bench_doc`/`stamped_doc`.
pub fn l6(src: &[SourceFile], allow: &Allow) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in src.iter().filter(|f| f.rel.starts_with("rust/src/")) {
        let toks = &f.lexed.toks;
        let tregions = test_regions(toks);
        for fi in functions(toks) {
            if in_regions(&tregions, fi.kw) {
                continue;
            }
            let Some((open, close)) = fi.body else { continue };
            let body = &toks[open + 1..close.min(toks.len())];
            let calls_save = body.windows(2).any(|w| {
                w[0].kind == TokKind::Ident && w[0].text == "save_json" && is_punct(&w[1], "(")
            });
            if !calls_save {
                continue;
            }
            let stamped = body.iter().any(|t| {
                t.kind == TokKind::Ident && (t.text == "bench_doc" || t.text == "stamped_doc")
            });
            let key = format!("{}::{}", under_src(&f.rel).unwrap_or(&f.rel), fi.name);
            if !stamped && !allowed(allow, "L6", &key) {
                out.push(Finding {
                    rule: "L6",
                    file: f.rel.clone(),
                    line: fi.line,
                    message: format!(
                        "`{}` writes results JSON without bench_doc/stamped_doc schema stamping",
                        fi.name
                    ),
                });
            }
        }
    }
    out
}

// ------------------------------------------------------------------ L7

/// L7: `unsafe` requires an adjacent `// SAFETY:` comment; files with no
/// unsafe at all must carry `#![forbid(unsafe_code)]` so future
/// `std::arch` work opts in explicitly.
pub fn l7(src: &[SourceFile], allow: &Allow) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in src.iter().filter(|f| f.rel.starts_with("rust/src/")) {
        let name = f.rel.rsplit('/').next().unwrap_or(&f.rel);
        if name == "lib.rs" || name == "main.rs" || name == "mod.rs" || f.rel.contains("/bin/") {
            continue;
        }
        let key = under_src(&f.rel).unwrap_or(&f.rel).to_string();
        if allowed(allow, "L7", &key) {
            continue;
        }
        let toks = &f.lexed.toks;
        let unsafe_toks: Vec<&Tok> =
            toks.iter().filter(|t| t.kind == TokKind::Ident && t.text == "unsafe").collect();
        if unsafe_toks.is_empty() {
            let has_forbid = toks.windows(8).any(|w| {
                is_punct(&w[0], "#")
                    && is_punct(&w[1], "!")
                    && is_punct(&w[2], "[")
                    && is_ident(&w[3], "forbid")
                    && is_punct(&w[4], "(")
                    && is_ident(&w[5], "unsafe_code")
                    && is_punct(&w[6], ")")
                    && is_punct(&w[7], "]")
            });
            if !has_forbid {
                out.push(Finding {
                    rule: "L7",
                    file: f.rel.clone(),
                    line: 1,
                    message: "file has no unsafe code — add #![forbid(unsafe_code)] so future \
                              unsafe must opt in explicitly"
                        .into(),
                });
            }
        } else {
            for t in unsafe_toks {
                let covered = f
                    .lexed
                    .safety_lines
                    .iter()
                    .any(|&s| s >= t.line.saturating_sub(3) && s <= t.line);
                if !covered {
                    out.push(Finding {
                        rule: "L7",
                        file: f.rel.clone(),
                        line: t.line,
                        message: "`unsafe` without a `// SAFETY:` comment within the 3 lines \
                                  above it"
                            .into(),
                    });
                }
            }
        }
    }
    out
}

// ------------------------------------------------------------------ L8

const L8_DIR: &str = "rust/src/mx/simd/";
const L8_SUFFIXES: [&str; 3] = ["_avx2", "_sse41", "_neon"];

/// Does the file carry an inner `#![cfg(target_arch = ...)]` gate?
fn has_arch_gate(toks: &[Tok]) -> bool {
    toks.windows(6).any(|w| {
        is_punct(&w[0], "#")
            && is_punct(&w[1], "!")
            && is_punct(&w[2], "[")
            && is_ident(&w[3], "cfg")
            && is_punct(&w[4], "(")
            && is_ident(&w[5], "target_arch")
    })
}

/// L8: every `#[target_feature]` kernel lives under `rust/src/mx/simd/`
/// in a module gated by `#![cfg(target_arch = ...)]`, is named for its
/// vector path (`*_avx2` / `*_sse41` / `*_neon`), and has a `*_swar`
/// scalar twin that is defined in the library and referenced from
/// `rust/tests/` (the bit-identity oracle L1 demands of parallel
/// kernels, extended to the vector ISA legs). Adjacent `// SAFETY:`
/// coverage of the `unsafe fn` itself is L7's job.
pub fn l8(src: &[SourceFile], tests: &[SourceFile], allow: &Allow) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut src_fns: BTreeSet<String> = BTreeSet::new();
    for f in src.iter().filter(|f| f.rel.starts_with("rust/src/")) {
        for fi in functions(&f.lexed.toks) {
            src_fns.insert(fi.name);
        }
    }
    let mut test_idents: BTreeSet<&str> = BTreeSet::new();
    for t in tests {
        for tok in &t.lexed.toks {
            if tok.kind == TokKind::Ident {
                test_idents.insert(tok.text.as_str());
            }
        }
    }
    for f in src.iter().filter(|f| f.rel.starts_with("rust/src/")) {
        let toks = &f.lexed.toks;
        let arch_gated = has_arch_gate(toks);
        for i in 0..toks.len().saturating_sub(2) {
            if !(is_punct(&toks[i], "#")
                && is_punct(&toks[i + 1], "[")
                && is_ident(&toks[i + 2], "target_feature"))
            {
                continue;
            }
            // the attributed item: next `fn <name>` within a short window
            let mut found: Option<(String, u32)> = None;
            for j in i + 3..(i + 40).min(toks.len().saturating_sub(1)) {
                if is_ident(&toks[j], "fn") && toks[j + 1].kind == TokKind::Ident {
                    found = Some((toks[j + 1].text.clone(), toks[j + 1].line));
                    break;
                }
            }
            let Some((name, line)) = found else { continue };
            if allowed(allow, "L8", &name) {
                continue;
            }
            let mut fail = |message: String| {
                out.push(Finding { rule: "L8", file: f.rel.clone(), line, message });
            };
            if !f.rel.starts_with(L8_DIR) {
                fail(format!(
                    "#[target_feature] fn `{name}` outside {L8_DIR} — arch kernels live in the \
                     simd module behind the dispatcher"
                ));
                continue;
            }
            if !arch_gated {
                fail(format!(
                    "#[target_feature] fn `{name}` in a module without an inner \
                     `#![cfg(target_arch = ...)]` gate"
                ));
            }
            let Some(base) =
                L8_SUFFIXES.iter().find_map(|s| name.strip_suffix(s).map(str::to_string))
            else {
                fail(format!(
                    "#[target_feature] fn `{name}` is not named for its vector path \
                     (*_avx2 / *_sse41 / *_neon)"
                ));
                continue;
            };
            let twin = format!("{base}_swar");
            if !src_fns.contains(&twin) {
                fail(format!("vector kernel `{name}` has no `{twin}` scalar twin"));
            } else if !test_idents.contains(twin.as_str()) {
                fail(format!(
                    "scalar twin `{twin}` of `{name}` is not referenced from any bit-identity \
                     test in rust/tests/"
                ));
            }
        }
    }
    out
}

// ------------------------------------------------------------------ L9

const L9_DIR: &str = "rust/src/chaos/";

/// Does a `#[cfg(` attribute open within the 40 tokens before `kw`?
fn has_cfg_attr(toks: &[Tok], kw: usize) -> bool {
    let start = kw.saturating_sub(40);
    toks[start..kw].windows(4).any(|w| {
        is_punct(&w[0], "#")
            && is_punct(&w[1], "[")
            && is_ident(&w[2], "cfg")
            && is_punct(&w[3], "(")
    })
}

/// L9: chaos injection seams stay plan-gated and drilled. Every
/// `fn inject_*` in the library must be referenced by name from
/// `rust/tests/` — a seam no chaos test ever fires is unproven risk
/// shipping in production builds — and must either live under
/// `rust/src/chaos/` (the module that acts only behind a `FaultPlan`)
/// or carry an explicit `#[cfg(...)]` gate. And any file outside
/// `rust/src/chaos/` that references an `inject_*` seam must itself
/// name `FaultPlan`, so no production path can fire a fault
/// unconditionally (DESIGN.md §13).
pub fn l9(src: &[SourceFile], tests: &[SourceFile], allow: &Allow) -> Vec<Finding> {
    let mut test_idents: BTreeSet<&str> = BTreeSet::new();
    for t in tests {
        for tok in &t.lexed.toks {
            if tok.kind == TokKind::Ident {
                test_idents.insert(tok.text.as_str());
            }
        }
    }
    let mut out = Vec::new();
    for f in src.iter().filter(|f| f.rel.starts_with("rust/src/")) {
        let toks = &f.lexed.toks;
        let in_chaos = f.rel.starts_with(L9_DIR);
        let plan_aware = toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "FaultPlan");
        let mut declared: BTreeSet<String> = BTreeSet::new();
        for fi in functions(toks) {
            if !fi.name.starts_with("inject_") {
                continue;
            }
            declared.insert(fi.name.clone());
            if allowed(allow, "L9", &fi.name) {
                continue;
            }
            if !test_idents.contains(fi.name.as_str()) {
                out.push(Finding {
                    rule: "L9",
                    file: f.rel.clone(),
                    line: fi.line,
                    message: format!(
                        "chaos seam `{}` is not referenced from any test in rust/tests/ — an \
                         undrilled injection seam is unproven risk",
                        fi.name
                    ),
                });
            }
            if !in_chaos && !has_cfg_attr(toks, fi.kw) {
                out.push(Finding {
                    rule: "L9",
                    file: f.rel.clone(),
                    line: fi.line,
                    message: format!(
                        "chaos seam `{}` declared outside {L9_DIR} without a #[cfg(...)] gate — \
                         seams live in the plan-gated chaos module",
                        fi.name
                    ),
                });
            }
        }
        if in_chaos {
            continue;
        }
        for t in toks.iter().filter(|t| t.kind == TokKind::Ident) {
            if !t.text.starts_with("inject_")
                || declared.contains(&t.text)
                || allowed(allow, "L9", &t.text)
            {
                continue;
            }
            if !plan_aware {
                out.push(Finding {
                    rule: "L9",
                    file: f.rel.clone(),
                    line: t.line,
                    message: format!(
                        "`{}` referenced without `FaultPlan` anywhere in the file — injection \
                         seams fire only behind a fault plan",
                        t.text
                    ),
                });
            }
        }
    }
    out
}

/// Run every rule and return findings sorted by (file, line, rule).
pub fn run_all(
    src: &[SourceFile],
    tests: &[SourceFile],
    allow: &Allow,
    manifest: &Manifest,
) -> Vec<Finding> {
    let mut out = Vec::new();
    out.extend(l1(src, tests, allow));
    out.extend(l2(src, allow));
    out.extend(l3(src, allow));
    out.extend(l4(src, allow));
    out.extend(l5(src, manifest));
    out.extend(l6(src, allow));
    out.extend(l7(src, allow));
    out.extend(l8(src, tests, allow));
    out.extend(l9(src, tests, allow));
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    out
}
