//! `mxlint`: a dependency-free static-analysis pass over this crate's
//! own sources, enforcing the bit-identity contracts the test suite can
//! only probe pointwise (see DESIGN.md §9 for the invariant catalog).
//!
//! The pipeline is: [`collect_sources`] walks `rust/src` and
//! `rust/tests`, [`lex::lex`] turns each file into a token stream, and
//! [`rules::run_all`] evaluates rules L1–L9 against them, honoring the
//! committed allowlist (`rust/lint.toml`) and byte-layout manifest
//! (`rust/lint.manifest`). The `mxlint` binary (`src/bin/mxlint.rs`)
//! adds `--json`, `--diff <rev>`, and `--update-manifest` on top.
//!
//! Everything here is deliberately `std`-only and deterministic:
//! sorted directory walks, sorted findings, insertion-ordered JSON —
//! so CI output diffs cleanly. `ci/mxlint_mirror.py` is a line-for-line
//! Python port of the lexer and rules used to regenerate the manifest
//! where no Rust toolchain exists; keep it in sync with this module.

pub mod lex;
pub mod rules;

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use crate::util::json::Json;
pub use rules::{Allow, Finding, Manifest, SourceFile};

/// Parsed `lint.toml`: per-rule allowlists with review reasons.
#[derive(Debug, Default)]
pub struct Config {
    pub allow: Allow,
}

/// Parse the `lint.toml` subset: `# comments`, `[allow.LX]` sections,
/// and `"key" = "reason"` entries.
pub fn parse_config(text: &str) -> Result<Config, String> {
    let mut allow = Allow::new();
    let mut section: Option<String> = None;
    for (idx, raw) in text.lines().enumerate() {
        let ln = idx + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let inner = rest.strip_suffix(']').ok_or(format!("line {ln}: unclosed section"))?;
            let rule = inner
                .strip_prefix("allow.")
                .ok_or(format!("line {ln}: unknown section `[{inner}]`"))?;
            section = Some(rule.to_string());
            allow.entry(rule.to_string()).or_default();
            continue;
        }
        let Some(rule) = &section else {
            return Err(format!("line {ln}: entry outside an [allow.*] section"));
        };
        let (key, rest) = parse_quoted(line).ok_or(format!("line {ln}: expected \"key\""))?;
        let rest = rest.trim_start();
        let rest = rest.strip_prefix('=').ok_or(format!("line {ln}: expected `=`"))?;
        let (reason, tail) =
            parse_quoted(rest.trim_start()).ok_or(format!("line {ln}: expected \"reason\""))?;
        let tail = tail.trim();
        if !tail.is_empty() && !tail.starts_with('#') {
            return Err(format!("line {ln}: trailing garbage `{tail}`"));
        }
        if reason.trim().is_empty() {
            return Err(format!("line {ln}: allowlist entry `{key}` needs a non-empty reason"));
        }
        allow.get_mut(rule).expect("section exists").push((key, reason));
    }
    Ok(Config { allow })
}

/// Parse a leading double-quoted string; returns (content, rest).
fn parse_quoted(s: &str) -> Option<(String, &str)> {
    let rest = s.strip_prefix('"')?;
    let end = rest.find('"')?;
    Some((rest[..end].to_string(), &rest[end + 1..]))
}

/// Parse `lint.manifest`: `version <n>`, an optional `store_version <n>`
/// (0 when absent — manifests predating the store layer), then
/// `fn <key> <hex16>` lines.
pub fn parse_manifest(text: &str) -> Result<Manifest, String> {
    let mut m = Manifest::default();
    let mut saw_version = false;
    for (idx, raw) in text.lines().enumerate() {
        let ln = idx + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(v) = line.strip_prefix("store_version ") {
            m.store_version =
                v.trim().parse().map_err(|_| format!("line {ln}: bad store_version `{v}`"))?;
            continue;
        }
        if let Some(v) = line.strip_prefix("version ") {
            m.version =
                v.trim().parse().map_err(|_| format!("line {ln}: bad version `{v}`"))?;
            saw_version = true;
            continue;
        }
        if let Some(rest) = line.strip_prefix("fn ") {
            let mut parts = rest.split_whitespace();
            let key = parts.next().ok_or(format!("line {ln}: missing key"))?;
            let hex = parts.next().ok_or(format!("line {ln}: missing hash"))?;
            let hash = u64::from_str_radix(hex, 16)
                .map_err(|_| format!("line {ln}: bad hash `{hex}`"))?;
            m.entries.push((key.to_string(), hash));
            continue;
        }
        return Err(format!("line {ln}: unrecognized `{line}`"));
    }
    if !saw_version {
        return Err("manifest has no `version` line".into());
    }
    Ok(m)
}

/// Render a manifest in the committed format (sorted keys).
pub fn render_manifest(m: &Manifest) -> String {
    let mut entries = m.entries.clone();
    entries.sort();
    let mut out = String::new();
    out.push_str("# Byte-layout manifest for mxlint rule L5. Regenerate with\n");
    out.push_str("#   cargo run --release --bin mxlint -- --update-manifest\n");
    out.push_str("# (or `python3 ci/mxlint_mirror.py --update-manifest` without a toolchain).\n");
    out.push_str(&format!("version {}\n", m.version));
    out.push_str(&format!("store_version {}\n", m.store_version));
    for (k, h) in &entries {
        out.push_str(&format!("fn {k} {h:016x}\n"));
    }
    out
}

/// Build the current manifest from sources (for `--update-manifest`).
pub fn current_manifest(src: &[SourceFile]) -> Manifest {
    Manifest {
        version: rules::checkpoint_version(src),
        store_version: rules::store_version(src),
        entries: rules::layout_hashes(src).into_iter().map(|(k, h, _, _)| (k, h)).collect(),
    }
}

fn walk_rs(dir: &Path, root: &Path, out: &mut Vec<SourceFile>) -> std::io::Result<()> {
    let mut names: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    names.sort();
    for path in names {
        if path.is_dir() {
            walk_rs(&path, root, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            let bytes = std::fs::read(&path)?;
            out.push(SourceFile { rel, lexed: lex::lex(&bytes) });
        }
    }
    Ok(())
}

/// Lex every `.rs` file under `rust/src` and `rust/tests` of `root`
/// (the repo root), in sorted order.
pub fn collect_sources(root: &Path) -> std::io::Result<(Vec<SourceFile>, Vec<SourceFile>)> {
    let mut src = Vec::new();
    let mut tests = Vec::new();
    walk_rs(&root.join("rust/src"), root, &mut src)?;
    let tdir = root.join("rust/tests");
    if tdir.is_dir() {
        walk_rs(&tdir, root, &mut tests)?;
    }
    Ok((src, tests))
}

/// Run all rules over in-memory sources — the library entry point the
/// binary and the self-run tests share.
pub fn lint(
    src: &[SourceFile],
    tests: &[SourceFile],
    cfg: &Config,
    manifest: &Manifest,
) -> Vec<Finding> {
    rules::run_all(src, tests, &cfg.allow, manifest)
}

/// Render findings as the `{"tool":"mxlint",...}` report consumed by
/// `ci/check_bench.py --mxlint-report`.
pub fn render_json(findings: &[Finding]) -> String {
    let mut arr = Json::arr();
    for f in findings {
        arr = arr.push(
            Json::obj()
                .set("rule", f.rule)
                .set("file", f.file.as_str())
                .set("line", f.line as u64)
                .set("message", f.message.as_str()),
        );
    }
    let mut counts: BTreeMap<&str, u64> = BTreeMap::new();
    for f in findings {
        *counts.entry(f.rule).or_insert(0) += 1;
    }
    let mut cobj = Json::obj();
    for (rule, n) in counts {
        cobj = cobj.set(rule, n);
    }
    cobj = cobj.set("total", findings.len() as u64);
    Json::obj()
        .set("tool", "mxlint")
        .set("schema_version", 1u64)
        .set("findings", arr)
        .set("counts", cobj)
        .pretty()
}

/// Changed-line sets per repo-relative file, from `git diff -U0 <rev>`.
/// Used by `mxlint --diff <rev>` to report findings only on new code.
pub fn changed_lines(root: &Path, rev: &str) -> Result<BTreeMap<String, BTreeSet<u32>>, String> {
    let out = std::process::Command::new("git")
        .arg("-C")
        .arg(root)
        .args(["diff", "-U0", "--no-color", rev, "--", "*.rs"])
        .output()
        .map_err(|e| format!("running git diff: {e}"))?;
    if !out.status.success() {
        return Err(format!(
            "git diff {rev} failed: {}",
            String::from_utf8_lossy(&out.stderr).trim()
        ));
    }
    let text = String::from_utf8_lossy(&out.stdout);
    let mut map: BTreeMap<String, BTreeSet<u32>> = BTreeMap::new();
    let mut file: Option<String> = None;
    for line in text.lines() {
        if let Some(path) = line.strip_prefix("+++ b/") {
            file = Some(path.to_string());
        } else if let Some(rest) = line.strip_prefix("@@ ") {
            let Some(file) = &file else { continue };
            // hunk header: `-a,b +c,d @@`
            let Some(plus) = rest.split_whitespace().find(|p| p.starts_with('+')) else {
                continue;
            };
            let nums = &plus[1..];
            let (start, count) = match nums.split_once(',') {
                Some((s, c)) => (s.parse().unwrap_or(0u32), c.parse().unwrap_or(0u32)),
                None => (nums.parse().unwrap_or(0u32), 1u32),
            };
            let set = map.entry(file.clone()).or_default();
            for l in start..start + count {
                set.insert(l);
            }
        }
    }
    Ok(map)
}

/// Keep only findings whose (file, line) is in the changed-line sets.
/// Repo-level findings (e.g. a stale manifest) are always kept.
pub fn filter_to_changed(
    findings: Vec<Finding>,
    changed: &BTreeMap<String, BTreeSet<u32>>,
) -> Vec<Finding> {
    findings
        .into_iter()
        .filter(|f| {
            if f.file == "rust/lint.manifest" {
                return true;
            }
            changed.get(&f.file).is_some_and(|lines| lines.contains(&f.line))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_round_trip() {
        let cfg = parse_config(
            "# header\n[allow.L3]\n\"dot8_i8\" = \"odd-byte extraction\" # trailing\n\n\
             [allow.L4]\n\"backend/hw.rs\" = \"sequencing-contract panics\"\n",
        )
        .unwrap();
        assert!(rules::allowed(&cfg.allow, "L3", "dot8_i8"));
        assert!(rules::allowed(&cfg.allow, "L4", "backend/hw.rs"));
        assert!(!rules::allowed(&cfg.allow, "L4", "backend/packed.rs"));
    }

    #[test]
    fn config_rejects_bad_lines() {
        assert!(parse_config("\"orphan\" = \"x\"\n").is_err());
        assert!(parse_config("[allow.L1]\n\"k\" =\n").is_err());
        assert!(parse_config("[allow.L1]\n\"k\" = \"\"\n").is_err());
        assert!(parse_config("[deny.L1]\n").is_err());
    }

    #[test]
    fn manifest_round_trip() {
        let m = Manifest {
            version: 2,
            store_version: 1,
            entries: vec![("mx/tensor.rs::to_bytes".into(), 0xdead_beef_0123_4567)],
        };
        let text = render_manifest(&m);
        let back = parse_manifest(&text).unwrap();
        assert_eq!(back.version, 2);
        assert_eq!(back.store_version, 1);
        assert_eq!(back.entries, m.entries);
        // Pre-store manifests have no store_version line: default to 0.
        let old = parse_manifest("version 2\nfn a 00ff\n").unwrap();
        assert_eq!(old.store_version, 0);
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(parse_manifest("fn a 00\n").is_err()); // no version
        assert!(parse_manifest("version x\n").is_err());
        assert!(parse_manifest("version 1\nwhat\n").is_err());
        assert!(parse_manifest("version 1\nfn key zz\n").is_err());
        assert!(parse_manifest("version 1\nstore_version x\n").is_err());
    }

    #[test]
    fn json_report_shape() {
        let findings = vec![Finding {
            rule: "L4",
            file: "rust/src/fleet/scheduler.rs".into(),
            line: 7,
            message: "msg".into(),
        }];
        let doc = Json::parse(&render_json(&findings)).unwrap();
        assert_eq!(doc.get("tool").and_then(Json::as_str), Some("mxlint"));
        assert_eq!(doc.get("schema_version").and_then(Json::as_f64), Some(1.0));
        assert_eq!(doc.get("findings").and_then(Json::items).map(<[Json]>::len), Some(1));
        let counts = doc.get("counts").unwrap();
        assert_eq!(counts.get("L4").and_then(Json::as_f64), Some(1.0));
        assert_eq!(counts.get("total").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn filter_to_changed_keeps_manifest_findings() {
        let mut changed = BTreeMap::new();
        changed.insert("a.rs".to_string(), BTreeSet::from([3u32]));
        let fs = vec![
            Finding { rule: "L4", file: "a.rs".into(), line: 3, message: String::new() },
            Finding { rule: "L4", file: "a.rs".into(), line: 9, message: String::new() },
            Finding { rule: "L5", file: "rust/lint.manifest".into(), line: 1, message: String::new() },
        ];
        let kept = filter_to_changed(fs, &changed);
        assert_eq!(kept.len(), 2);
    }
}
