//! The precision-scalable MX MAC unit (paper §III-A, Fig. 3).
//!
//! One [`MacUnit`] models one MAC lane of the PE array: per cycle it
//! consumes 1 / 4 / 8 element pairs (INT8 / FP8-FP6 / FP4), produces one
//! Sum-Together result through the L1/L2 hierarchy, applies the combined
//! shared exponent of the input blocks, and accumulates output-stationary
//! into an FP32 register. Numerics are bit-faithful to the datapath;
//! every micro-op increments [`Events`] for the energy model.

#![forbid(unsafe_code)]

use crate::arith::adders::{l1_fp4_shift_sum, l1_sum_partials, l2_add, L2Path};
use crate::arith::mult2::mul_mag;
use crate::arith::{Events, Mode};
use crate::mx::element::ElementFormat;

/// Implementation variants compared in the paper's Table II.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MacVariant {
    /// Proposed: +2-bit mantissa extension at L2 and mode-specific
    /// bypasses. Meets 500 MHz. (Table II row 3.)
    ExtMantissaBypass,
    /// Mantissa extension but no bypass network: the unbalanced critical
    /// path only closes timing at 417 MHz. (Table II row 2.)
    ExtMantissaNoBypass,
    /// Normalize every L2 input instead of extending the adder: meets
    /// 500 MHz but pays normalization area/energy. (Table II row 1.)
    NormalizeL2,
}

impl MacVariant {
    /// Achievable clock in MHz (synthesis result the model reproduces).
    pub fn freq_mhz(&self) -> f64 {
        match self {
            MacVariant::ExtMantissaNoBypass => 417.0,
            _ => 500.0,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            MacVariant::ExtMantissaBypass => "ext+bypass",
            MacVariant::ExtMantissaNoBypass => "ext-no-bypass",
            MacVariant::NormalizeL2 => "normalize-l2",
        }
    }
}

/// One precision-scalable MAC lane.
#[derive(Debug, Clone)]
pub struct MacUnit {
    pub mode: Mode,
    pub variant: MacVariant,
    acc: f32,
    /// Previous operand-register contents, for switching-activity counts.
    prev_operands: u64,
    pub events: Events,
}

impl MacUnit {
    pub fn new(mode: Mode, variant: MacVariant) -> Self {
        Self { mode, variant, acc: 0.0, prev_operands: 0, events: Events::default() }
    }

    /// Current accumulator value.
    pub fn acc(&self) -> f32 {
        self.acc
    }

    /// Clear the accumulator (new output tile).
    pub fn reset_acc(&mut self) {
        self.acc = 0.0;
    }

    /// Clear the operand register (tile-context boundary). Toggle
    /// counting restarts from an all-zeros register, which makes each
    /// output tile's event counts independent of tile traversal order —
    /// the property that lets the tile-parallel PE-array walk reproduce
    /// the serial walk's `Events` exactly.
    pub fn reset_operand_reg(&mut self) {
        self.prev_operands = 0;
    }

    /// Drain counters (e.g. between benchmark phases).
    pub fn take_events(&mut self) -> Events {
        std::mem::take(&mut self.events)
    }

    /// INT8 cycle (Fig. 3a): one INT8 x INT8 product through all sixteen
    /// 2-bit multipliers; exponent adders inactive. `scale_exp` is the
    /// combined shared exponent of the two blocks **including** MXINT8's
    /// implied 2^-6 per element (i.e. `sxA + sxB - 12`).
    pub fn cycle_int8(&mut self, a: i8, b: i8, scale_exp: i32) {
        debug_assert_eq!(self.mode, Mode::Int8);
        self.touch_operands((a as u8 as u64) | ((b as u8 as u64) << 8));
        // sign-magnitude conversion (the INT8-mode L1 critical path)
        let (sa, ma) = sign_mag(a);
        let (sb, mb) = sign_mag(b);
        let (_, partials) = mul_mag(ma, mb, 4, &mut self.events);
        let mag = l1_sum_partials(partials.as_slice(), &mut self.events);
        let prod = sa * sb * mag as i64;
        // single pre-aligned term: bypasses L2 alignment
        let v = l2_add(&[(prod, 0)], L2Path::BypassInt, &mut self.events);
        self.accumulate(v, scale_exp);
        self.events.cycles += 1;
        self.events.mul_ops += 1;
    }

    /// FP8/FP6 cycle (Fig. 3b): four parallel products, each four 2-bit
    /// multipliers (mantissa) + one 5-bit exponent adder, aligned and
    /// added at L2. `scale_exp = sxA + sxB` (element mantissa scaling is
    /// handled internally from the format).
    pub fn cycle_fp86(&mut self, fmt: ElementFormat, pairs: &[(u8, u8); 4], scale_exp: i32) {
        debug_assert_eq!(self.mode, Mode::Fp8Fp6);
        debug_assert!(matches!(
            fmt,
            ElementFormat::E5M2 | ElementFormat::E4M3 | ElementFormat::E3M2 | ElementFormat::E2M3
        ));
        let mut packed = 0u64;
        for (i, &(a, b)) in pairs.iter().enumerate() {
            packed |= (a as u64) << (16 * i) | (b as u64) << (16 * i + 8);
        }
        self.touch_operands(packed);
        let mb = fmt.mant_bits() as i32;
        let mut terms = [(0i64, 0i32); 4];
        for (i, &(ca, cb)) in pairs.iter().enumerate() {
            let (sa, ea, ma) = fmt.fp_parts(ca);
            let (sb, eb, mbm) = fmt.fp_parts(cb);
            self.events.exp_add5 += 1;
            let (_, partials) = mul_mag(ma, mbm, 2, &mut self.events);
            let mant_prod = l1_sum_partials(partials.as_slice(), &mut self.events);
            // value = s * mant_prod * 2^(ea+eb-2*mb); keep -2mb in the term
            terms[i] = ((sa * sb) as i64 * mant_prod as i64, ea + eb - 2 * mb);
        }
        let v = l2_add(&terms, L2Path::Aligned, &mut self.events);
        self.accumulate(v, scale_exp);
        self.events.cycles += 1;
        self.events.mul_ops += 4;
    }

    /// FP4 cycle (Fig. 3c): eight parallel E2M1 x E2M1 products, each one
    /// 2-bit multiplier + one 2-bit exponent adder; two L1 shift-sum
    /// groups of four; L2 alignment bypassed. `scale_exp = sxA + sxB`.
    pub fn cycle_fp4(&mut self, pairs: &[(u8, u8); 8], scale_exp: i32) {
        debug_assert_eq!(self.mode, Mode::Fp4);
        let fmt = ElementFormat::E2M1;
        let mut packed = 0u64;
        for (i, &(a, b)) in pairs.iter().enumerate() {
            packed |= (a as u64) << (8 * i) | (b as u64) << (8 * i + 4);
        }
        self.touch_operands(packed);
        let mb = fmt.mant_bits() as i32; // 1
        let mut products = [(0i32, 0u32, 0u32); 8];
        for (i, &(ca, cb)) in pairs.iter().enumerate() {
            let (sa, ea, ma) = fmt.fp_parts(ca);
            let (sb, eb, mbm) = fmt.fp_parts(cb);
            self.events.exp_add2 += 1;
            let (mant_prod, _) = mul_mag(ma, mbm, 1, &mut self.events);
            // E2M1 exponents are >= emin = 0, so ea+eb in 0..=4 ("E3M4")
            products[i] = (sa * sb, (ea + eb) as u32, mant_prod);
        }
        let s0 = l1_fp4_shift_sum(&products[..4], &mut self.events);
        let s1 = l1_fp4_shift_sum(&products[4..], &mut self.events);
        // both L1 sums share exponent scale 2^(-2*mb): bypass L2 alignment
        let v = l2_add(&[(s0, -2 * mb), (s1, -2 * mb)], L2Path::BypassFp4, &mut self.events);
        self.accumulate(v, scale_exp);
        self.events.cycles += 1;
        self.events.mul_ops += 8;
    }

    /// FP32 accumulation (the "orange" adder + green register in Fig. 3):
    /// shared exponent applied to the L2 output, then one FP32 RNE add.
    fn accumulate(&mut self, l2_out: f64, scale_exp: i32) {
        self.events.shared_exp_add += 1;
        self.events.acc_add += 1;
        let scaled = l2_out * (scale_exp as f64).exp2();
        let new = (self.acc as f64 + scaled) as f32;
        self.events.acc_reg_toggles += (self.acc.to_bits() ^ new.to_bits()).count_ones() as u64;
        self.acc = new;
    }

    /// Operand-register switching activity.
    fn touch_operands(&mut self, packed: u64) {
        self.events.input_toggles += (self.prev_operands ^ packed).count_ones() as u64;
        self.prev_operands = packed;
    }
}

#[inline]
fn sign_mag(x: i8) -> (i64, u32) {
    if x < 0 {
        (-1, (-(x as i32)) as u32)
    } else {
        (1, x as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::block::quantize_block;
    use crate::util::rng::Pcg64;
    use crate::util::testing::{assert_ulps, forall};

    #[test]
    fn int8_dot_product_bit_exact() {
        // 8-cycle INT8 dot product == i32 golden, scaled by 2^scale
        forall(
            0x17,
            500,
            |r| {
                let a: Vec<i8> = (0..8).map(|_| r.int_range(-127, 127) as i8).collect();
                let b: Vec<i8> = (0..8).map(|_| r.int_range(-127, 127) as i8).collect();
                let scale = r.int_range(-20, 8) as i32;
                (a, b, scale)
            },
            |(a, b, scale)| {
                let mut mac = MacUnit::new(Mode::Int8, MacVariant::ExtMantissaBypass);
                for i in 0..8 {
                    mac.cycle_int8(a[i], b[i], *scale);
                }
                let golden: i64 = (0..8).map(|i| a[i] as i64 * b[i] as i64).sum();
                let want = (golden as f64 * (*scale as f64).exp2()) as f32;
                if mac.acc() != want {
                    return Err(format!("{} != {}", mac.acc(), want));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn int8_event_counts_per_cycle() {
        let mut mac = MacUnit::new(Mode::Int8, MacVariant::ExtMantissaBypass);
        mac.cycle_int8(-77, 33, 0);
        let e = mac.events;
        assert_eq!(e.mult2, 16, "all sixteen 2-bit multipliers work together");
        assert_eq!(e.exp_add5 + e.exp_add2, 0, "exponent adders inactive");
        assert_eq!(e.l2_bypass, 1, "INT8 bypasses L2 alignment");
        assert_eq!(e.l2_align, 0);
        assert_eq!(e.acc_add, 1);
        assert_eq!(e.mul_ops, 1);
    }

    fn fp_dot_golden(fmt: ElementFormat, codes: &[(u8, u8)], scale_exp: i32) -> f64 {
        codes
            .iter()
            .map(|&(a, b)| fmt.decode(a) * fmt.decode(b))
            .sum::<f64>()
            * (scale_exp as f64).exp2()
    }

    #[test]
    fn fp86_dot_product_matches_decoded_golden() {
        for fmt in [ElementFormat::E5M2, ElementFormat::E4M3, ElementFormat::E3M2, ElementFormat::E2M3] {
            forall(
                0xF8 + fmt.bits() as u64,
                400,
                |r| {
                    let n_codes = fmt.code_count() as u64;
                    let pairs: Vec<(u8, u8)> = (0..8)
                        .map(|_| {
                            let mut pick = || loop {
                                let c = r.below(n_codes) as u8;
                                if !fmt.is_special(c) {
                                    break c;
                                }
                            };
                            (pick(), pick())
                        })
                        .collect();
                    let scale = r.int_range(-10, 10) as i32;
                    (pairs, scale)
                },
                |(pairs, scale)| {
                    let mut mac = MacUnit::new(Mode::Fp8Fp6, MacVariant::ExtMantissaBypass);
                    mac.cycle_fp86(fmt, &pairs[0..4].try_into().unwrap(), *scale);
                    mac.cycle_fp86(fmt, &pairs[4..8].try_into().unwrap(), *scale);
                    let golden = fp_dot_golden(fmt, pairs, *scale);
                    // error budget: per-cycle window truncation is bounded
                    // by 2^-27 of the cycle's largest product, plus two
                    // FP32 accumulation roundings.
                    let max_prod = pairs
                        .iter()
                        .map(|&(a, b)| (fmt.decode(a) * fmt.decode(b)).abs())
                        .fold(0.0f64, f64::max)
                        * (*scale as f64).exp2();
                    let tol = 2.0 * 5.0 * max_prod * (-27f64).exp2()
                        + 2.0 * (golden.abs() + max_prod) * (-24f64).exp2()
                        + 1e-300;
                    if (mac.acc() as f64 - golden).abs() > tol {
                        return Err(format!(
                            "{fmt:?}: {} vs {golden} (tol {tol})",
                            mac.acc()
                        ));
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn fp86_event_counts_per_cycle() {
        let mut mac = MacUnit::new(Mode::Fp8Fp6, MacVariant::ExtMantissaBypass);
        let pairs = [(0x3c, 0x3c), (0x44, 0xbc), (0x01, 0x7b), (0x00, 0x3c)];
        mac.cycle_fp86(ElementFormat::E5M2, &pairs, 0);
        let e = mac.events;
        assert_eq!(e.mult2, 16, "4 products x 4 mult2 each");
        assert_eq!(e.exp_add5, 4, "one 5-bit exponent adder per product");
        assert_eq!(e.l2_align, 4, "all four terms aligned");
        assert_eq!(e.l2_bypass, 0);
        assert_eq!(e.mul_ops, 4);
    }

    #[test]
    fn fp4_dot_product_exact() {
        // FP4 products and the shift-sum are exact integers; the single
        // FP32 accumulation rounds once -> exactly representable sums
        // must match the f64 golden bit-for-bit.
        forall(
            0xF4,
            500,
            |r| {
                let pairs: Vec<(u8, u8)> =
                    (0..8).map(|_| (r.bits(4) as u8, r.bits(4) as u8)).collect();
                let scale = r.int_range(-8, 8) as i32;
                (pairs, scale)
            },
            |(pairs, scale)| {
                let mut mac = MacUnit::new(Mode::Fp4, MacVariant::ExtMantissaBypass);
                mac.cycle_fp4(pairs.as_slice().try_into().unwrap(), *scale);
                let golden = fp_dot_golden(ElementFormat::E2M1, pairs, *scale);
                if mac.acc() != golden as f32 {
                    return Err(format!("{} != {golden}", mac.acc()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fp4_event_counts_half_parallelism() {
        let mut mac = MacUnit::new(Mode::Fp4, MacVariant::ExtMantissaBypass);
        let pairs = [(1u8, 2u8); 8];
        mac.cycle_fp4(&pairs, 0);
        let e = mac.events;
        assert_eq!(e.mult2, 8, "FP4 uses only 8 of 16 multipliers (BW limit)");
        assert_eq!(e.exp_add2, 8, "one 2-bit exponent adder per product");
        assert_eq!(e.l1_shift, 8, "direct mantissa shifting");
        assert_eq!(e.l2_bypass, 1, "FP4 bypasses L2 alignment");
        assert_eq!(e.mul_ops, 8);
    }

    #[test]
    fn block_dot_with_shared_exponents_matches_dequantized_math() {
        // end-to-end over real quantized blocks: MAC result over one
        // 8-element lane == dot(dequantized) within FP32 rounding
        let mut rng = Pcg64::new(0xB10C);
        for fmt in [ElementFormat::Int8, ElementFormat::E4M3, ElementFormat::E2M1] {
            for _ in 0..50 {
                let xs: Vec<f32> = (0..8).map(|_| rng.normal_f32() * 3.0).collect();
                let ys: Vec<f32> = (0..8).map(|_| rng.normal_f32() * 3.0).collect();
                let bx = quantize_block(&xs, fmt);
                let by = quantize_block(&ys, fmt);
                let golden: f64 =
                    (0..8).map(|i| bx.decode(i) * by.decode(i)).sum();

                let acc = match fmt {
                    ElementFormat::Int8 => {
                        let mut mac = MacUnit::new(Mode::Int8, MacVariant::ExtMantissaBypass);
                        let se = bx.scale_exp + by.scale_exp - 12;
                        for i in 0..8 {
                            mac.cycle_int8(bx.codes[i] as i8, by.codes[i] as i8, se);
                        }
                        mac.acc()
                    }
                    ElementFormat::E2M1 => {
                        let mut mac = MacUnit::new(Mode::Fp4, MacVariant::ExtMantissaBypass);
                        let pairs: Vec<(u8, u8)> =
                            (0..8).map(|i| (bx.codes[i], by.codes[i])).collect();
                        mac.cycle_fp4(
                            pairs.as_slice().try_into().unwrap(),
                            bx.scale_exp + by.scale_exp,
                        );
                        mac.acc()
                    }
                    _ => {
                        let mut mac = MacUnit::new(Mode::Fp8Fp6, MacVariant::ExtMantissaBypass);
                        let se = bx.scale_exp + by.scale_exp;
                        for c in 0..2 {
                            let pairs: Vec<(u8, u8)> =
                                (4 * c..4 * c + 4).map(|i| (bx.codes[i], by.codes[i])).collect();
                            mac.cycle_fp86(fmt, pairs.as_slice().try_into().unwrap(), se);
                        }
                        mac.acc()
                    }
                };
                assert_ulps(acc, golden as f32, 2, &format!("{fmt:?}"));
            }
        }
    }

    #[test]
    fn mode_cycle_counts_match_paper() {
        assert_eq!(Mode::Int8.cycles_per_block(), 8);
        assert_eq!(Mode::Fp8Fp6.cycles_per_block(), 2);
        assert_eq!(Mode::Fp4.cycles_per_block(), 1);
    }

    #[test]
    fn variant_frequencies_match_table2() {
        assert_eq!(MacVariant::ExtMantissaBypass.freq_mhz(), 500.0);
        assert_eq!(MacVariant::ExtMantissaNoBypass.freq_mhz(), 417.0);
        assert_eq!(MacVariant::NormalizeL2.freq_mhz(), 500.0);
    }

    #[test]
    fn accumulator_resets() {
        let mut mac = MacUnit::new(Mode::Int8, MacVariant::ExtMantissaBypass);
        mac.cycle_int8(10, 10, 0);
        assert!(mac.acc() != 0.0);
        mac.reset_acc();
        assert_eq!(mac.acc(), 0.0);
    }
}
