//! The hierarchical L1/L2 accumulator (paper §III-B, Fig. 4).
//!
//! **L1** is a plain integer compressor: in INT8/FP8/FP6 mode it sums the
//! shifted 4-bit partial products of one multiplication; in FP4 mode it
//! sums four *completed* products ("E3M4": 4-bit mantissa, exponent 0..4)
//! by direct mantissa shifting — no max-exponent search, exploiting the
//! tiny exponent range. The same adder serves all modes (+2 bits in FP4).
//!
//! **L2** adds the per-cycle terms in an FP32-grade datapath: align each
//! term to the largest exponent within a 26-bit mantissa window extended
//! by 2 guard bits (so non-normalized inputs from subnormal-heavy narrow
//! formats never lose accuracy vs. FP32), then one wide add. INT8 and FP4
//! terms arrive pre-aligned (single exponent) and **bypass** the alignment
//! stage — the paper's critical-path balancing optimization.

#![forbid(unsafe_code)]

use crate::arith::Events;

/// Mantissa window of the L2 alignment datapath: 26-bit adder + 2-bit
/// extension for non-normalized inputs (paper §III-B "L2 Adder").
pub const L2_WINDOW: i32 = 28;

/// Which L2 path a term set takes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum L2Path {
    /// FP8/FP6: full alignment network.
    Aligned,
    /// INT8: single pre-aligned integer term — alignment bypassed.
    BypassInt,
    /// FP4: L1 already shift-summed — alignment bypassed.
    BypassFp4,
}

/// L1 compressor: exact sum of pre-shifted partial products.
///
/// Counts one `l1_add` activation per partial (compressor cell energy
/// scales with the number of terms squeezed).
pub fn l1_sum_partials(partials: &[u32], ev: &mut Events) -> u32 {
    ev.l1_add += partials.len() as u64;
    partials.iter().sum()
}

/// L1 FP4 path: sum up to four completed FP4 products by shifting each
/// 4-bit mantissa left by its 0..4 exponent (no alignment search).
///
/// `products`: (sign, exponent-sum in 0..=4, mantissa product < 16).
/// Returns the exact signed sum at exponent 0, i.e. value = sum * 2^0
/// in mantissa-product units.
pub fn l1_fp4_shift_sum(products: &[(i32, u32, u32)], ev: &mut Events) -> i64 {
    let mut acc = 0i64;
    for &(s, e, m) in products {
        debug_assert!(e <= 4, "E3M4 exponent range is 0..4");
        debug_assert!(m < 16, "M4 mantissa");
        ev.l1_shift += 1;
        ev.l1_add += 1;
        acc += s as i64 * ((m as i64) << e);
    }
    acc
}

/// L2 add: combine terms `value_i = mant_i * 2^(exp_i)` (signed mantissas)
/// into one real value through the chosen path.
///
/// `Aligned` models the hardware window: terms more than [`L2_WINDOW`]
/// binades below the largest exponent contribute only a sticky bit (which
/// nudges the LSB, preserving FP32-grade rounding behaviour). Bypass paths
/// are exact integer adds at a common exponent.
pub fn l2_add(terms: &[(i64, i32)], path: L2Path, ev: &mut Events) -> f64 {
    match path {
        L2Path::BypassInt | L2Path::BypassFp4 => {
            ev.l2_bypass += 1;
            ev.l2_add += 1;
            let e = terms.first().map(|t| t.1).unwrap_or(0);
            debug_assert!(terms.iter().all(|t| t.1 == e), "bypass terms must share exponent");
            let sum: i64 = terms.iter().map(|t| t.0).sum();
            sum as f64 * exp2(e)
        }
        L2Path::Aligned => {
            ev.l2_add += 1;
            if terms.is_empty() {
                return 0.0;
            }
            // The window anchors on the MSB of the largest term *value*,
            // not its scale exponent: inputs are non-normalized (mantissa
            // products span 1..8 significant bits), which is exactly why
            // the adder is extended instead of normalizing each input
            // (paper §III-B "L2 Adder").
            let msb = |m: i64, e: i32| e + 63 - (m.unsigned_abs().leading_zeros() as i32);
            let anchor = terms
                .iter()
                .filter(|t| t.0 != 0)
                .map(|&(m, e)| msb(m, e))
                .max();
            let Some(anchor) = anchor else { return 0.0 };
            let floor_e = anchor - L2_WINDOW + 1; // lowest kept bit weight
            let mut acc: i128 = 0;
            let mut sticky = false;
            for &(m, e) in terms {
                ev.l2_align += 1;
                if m == 0 {
                    continue;
                }
                if e >= floor_e {
                    acc += (m as i128) << (e - floor_e);
                } else {
                    let drop = (floor_e - e) as u32;
                    if drop < 64 {
                        // sign-magnitude alignment truncates the dropped
                        // bits toward zero; they fold into the sticky bit
                        let q = m / (1i64 << drop);
                        acc += q as i128;
                        sticky |= m != q << drop;
                    } else {
                        sticky = true;
                    }
                }
            }
            if sticky && acc & 1 == 0 {
                // sticky nudge keeps round-to-nearest behaviour downstream
                acc |= 1;
            }
            acc as f64 * exp2(floor_e)
        }
    }
}

#[inline]
fn exp2(e: i32) -> f64 {
    (e as f64).exp2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::util::testing::forall;

    #[test]
    fn l1_sums_exactly() {
        let mut ev = Events::default();
        assert_eq!(l1_sum_partials(&[1, 2 << 2, 3 << 4], &mut ev), 1 + 8 + 48);
        assert_eq!(ev.l1_add, 3);
    }

    #[test]
    fn l1_fp4_matches_direct_evaluation() {
        let mut ev = Events::default();
        // products: +-m*2^e
        let ps = [(1, 0, 9), (-1, 4, 15), (1, 2, 3), (-1, 1, 1)];
        let want: i64 = ps.iter().map(|&(s, e, m): &(i32, u32, u32)| s as i64 * ((m as i64) << e)).sum();
        assert_eq!(l1_fp4_shift_sum(&ps, &mut ev), want);
        assert_eq!(ev.l1_shift, 4);
    }

    #[test]
    fn l2_bypass_exact() {
        let mut ev = Events::default();
        let v = l2_add(&[(100, -3), (-37, -3)], L2Path::BypassInt, &mut ev);
        assert_eq!(v, 63.0 / 8.0);
        assert_eq!(ev.l2_bypass, 1);
        assert_eq!(ev.l2_align, 0);
    }

    #[test]
    fn l2_aligned_exact_within_window() {
        let mut ev = Events::default();
        // all bits fall inside the 28-bit value-anchored window -> exact
        // (anchor = msb(225 * 2^10) = 2^17, floor = 2^-10)
        let terms = [(225i64, 10), (-37, 3), (9, -5), (1, -8)];
        let want: f64 = terms.iter().map(|&(m, e)| m as f64 * (e as f64).exp2()).sum();
        assert_eq!(l2_add(&terms, L2Path::Aligned, &mut ev), want);
        assert_eq!(ev.l2_align, 4);
    }

    #[test]
    fn l2_aligned_far_terms_only_sticky() {
        let mut ev = Events::default();
        // term 2^-40 below the max: outside the 28-bit window
        let terms = [(1i64 << 7, 20), (1, -40)];
        let v = l2_add(&terms, L2Path::Aligned, &mut ev);
        let exact = 128.0 * (20f64).exp2() + (-40f64).exp2();
        // error far below f32 resolution of the result
        let ulp32 = (exact as f32).to_bits();
        let got32 = (v as f32).to_bits();
        assert!(ulp32.abs_diff(got32) <= 1, "{v} vs {exact}");
    }

    #[test]
    fn l2_aligned_close_to_f64_for_random_fp_products() {
        // random FP8-like products: |mant| < 256, exp in [-40, 40]
        forall(
            0x12,
            2000,
            |r: &mut Pcg64| {
                let n = 4;
                (0..n)
                    .map(|_| {
                        let m = r.int_range(-255, 255);
                        let e = r.int_range(-40, 40) as i32;
                        (m, e)
                    })
                    .collect::<Vec<_>>()
            },
            |terms| {
                let mut ev = Events::default();
                let got = l2_add(terms, L2Path::Aligned, &mut ev);
                let exact: f64 = terms.iter().map(|&(m, e)| m as f64 * (e as f64).exp2()).sum();
                // FP32-grade accuracy relative to the largest *term*: the
                // window keeps 28 bits below the max-term MSB; each
                // dropped term truncates by < 1 window-LSB and the sticky
                // nudge adds <= 1 more, so the error is bounded by
                // (n+1) * 2^(anchor-27) — far below one FP32 ulp of the
                // dominant term even under catastrophic cancellation.
                let anchor = terms
                    .iter()
                    .map(|&(m, e)| m.abs() as f64 * (e as f64).exp2())
                    .fold(0.0f64, f64::max);
                let tol = (terms.len() + 1) as f64 * anchor * (-27f64).exp2() + 1e-300;
                if (got - exact).abs() > tol {
                    return Err(format!("{got} vs {exact} (tol {tol})"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn l2_all_zero_terms() {
        let mut ev = Events::default();
        assert_eq!(l2_add(&[(0, 5), (0, -3)], L2Path::Aligned, &mut ev), 0.0);
    }
}
