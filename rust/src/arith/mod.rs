//! Bit-exact model of the paper's precision-scalable MX MAC unit (§III).
//!
//! The unit is built from **sixteen elementary 2-bit multipliers** plus a
//! **hierarchical two-level accumulator** and operates in three modes:
//!
//! | mode     | products/cycle | mult2 used | exponent adders |
//! |----------|----------------|------------|-----------------|
//! | INT8     | 1 (INT8xINT8)  | 16         | — (inactive)    |
//! | FP8/FP6  | 4              | 4 x 4      | 4 x 5-bit       |
//! | FP4      | 8 (BW-limited) | 8 x 1      | 8 x 2-bit       |
//!
//! The **L1 adder** assembles partial products (INT8/FP8/FP6) or
//! shift-sums completed FP4 products ("E3M4", exponent range 0..4); the
//! **L2 adder** aligns and adds in an FP32 datapath with a 26-bit mantissa
//! adder extended by 2 bits to absorb non-normalized (subnormal-sourced)
//! inputs, with INT8/FP4 **bypassing** the alignment stage (the paper's
//! critical-path balancing trick). A Sum-Together scheme yields one output
//! per MAC per cycle in every mode, accumulated output-stationary in FP32.
//!
//! Every micro-operation increments an [`Events`] counter; the energy
//! model (`crate::energy`) converts event counts into pJ, which is how
//! Tables II/IV and Fig. 7 are regenerated without synthesis.

pub mod adders;
pub mod mac;
pub mod mult2;

pub use adders::{l1_fp4_shift_sum, l1_sum_partials, l2_add, L2Path};
pub use mac::{MacUnit, MacVariant};
pub use mult2::{mul2, mul_mag};

/// MAC operating mode (paper Fig. 3 a/b/c).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    Int8,
    Fp8Fp6,
    Fp4,
}

impl Mode {
    /// Element pairs consumed per cycle (the Sum-Together width).
    pub const fn pairs_per_cycle(&self) -> usize {
        match self {
            Mode::Int8 => 1,
            Mode::Fp8Fp6 => 4,
            Mode::Fp4 => 8,
        }
    }

    /// Cycles for one 8-deep dot product (one 8x8 block-pair per MAC lane).
    pub const fn cycles_per_block(&self) -> usize {
        8 / self.pairs_per_cycle()
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mode::Int8 => "int8",
            Mode::Fp8Fp6 => "fp8fp6",
            Mode::Fp4 => "fp4",
        }
    }
}

/// Micro-operation counters — the currency of the energy model.
///
/// One `Events` instance accumulates over a run; the energy model prices
/// each field (pJ/event) and sums. Fields mirror the paper's Fig. 7
/// component breakdown.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Events {
    /// Elementary 2-bit x 2-bit multiplications.
    pub mult2: u64,
    /// 5-bit exponent additions (FP8/FP6 mode).
    pub exp_add5: u64,
    /// 2-bit exponent additions (FP4 mode).
    pub exp_add2: u64,
    /// L1 partial-product compressor activations (per 4-term group).
    pub l1_add: u64,
    /// L1 variable-shift operations (FP4 path).
    pub l1_shift: u64,
    /// L2 alignment (shift to common exponent) operations.
    pub l2_align: u64,
    /// L2 wide-mantissa additions.
    pub l2_add: u64,
    /// L2 alignment stages skipped via the bypass network.
    pub l2_bypass: u64,
    /// FP32 accumulation additions (the "orange" adder).
    pub acc_add: u64,
    /// Accumulation-register bit toggles (switching activity).
    pub acc_reg_toggles: u64,
    /// Shared-exponent additions at PE level.
    pub shared_exp_add: u64,
    /// Input operand register-bank bit toggles.
    pub input_toggles: u64,
    /// Total MAC cycles executed.
    pub cycles: u64,
    /// Multiplication OPs completed (element products).
    pub mul_ops: u64,
}

impl Events {
    pub fn add(&mut self, o: &Events) {
        self.mult2 += o.mult2;
        self.exp_add5 += o.exp_add5;
        self.exp_add2 += o.exp_add2;
        self.l1_add += o.l1_add;
        self.l1_shift += o.l1_shift;
        self.l2_align += o.l2_align;
        self.l2_add += o.l2_add;
        self.l2_bypass += o.l2_bypass;
        self.acc_add += o.acc_add;
        self.acc_reg_toggles += o.acc_reg_toggles;
        self.shared_exp_add += o.shared_exp_add;
        self.input_toggles += o.input_toggles;
        self.cycles += o.cycles;
        self.mul_ops += o.mul_ops;
    }
}
