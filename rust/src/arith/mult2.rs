//! The sixteen elementary 2-bit multipliers (paper §III-A).
//!
//! Everything the MAC multiplies is decomposed into radix-4 digits and
//! produced by 2-bit x 2-bit unsigned multiplications — one INT8 magnitude
//! product uses all sixteen, one FP8/FP6 mantissa product uses four, one
//! FP4 mantissa product uses one. The decomposition here is bit-exact by
//! construction and verified exhaustively against native multiplication.

#![forbid(unsafe_code)]

use crate::arith::Events;

/// One elementary 2-bit x 2-bit multiplication (result fits in 4 bits).
#[inline]
pub fn mul2(a: u8, b: u8, ev: &mut Events) -> u8 {
    debug_assert!(a < 4 && b < 4);
    ev.mult2 += 1;
    a * b
}

/// Multiply two unsigned magnitudes of up to `digits`*2 bits via the
/// 2-bit multiplier array, returning the exact product and the vector of
/// shifted partial products (which the L1 adder then compresses).
///
/// `digits` = 4 models the INT8 magnitude path (16 mult2), `digits` = 2
/// the FP8/FP6 mantissa path (4 mult2), `digits` = 1 the FP4 path.
pub fn mul_mag(a: u32, b: u32, digits: usize, ev: &mut Events) -> (u32, Partials) {
    debug_assert!(a < 1 << (2 * digits) && b < 1 << (2 * digits));
    // §Perf: partials live in a fixed stack array (max 16 for the INT8
    // path) — this loop runs once per simulated mantissa product and a
    // heap Vec here cost ~35% of whole-array simulation time.
    let mut partials = Partials { buf: [0; 16], len: 0 };
    for i in 0..digits {
        for j in 0..digits {
            let ai = ((a >> (2 * i)) & 3) as u8;
            let bj = ((b >> (2 * j)) & 3) as u8;
            let p = mul2(ai, bj, ev) as u32;
            partials.buf[partials.len] = p << (2 * (i + j));
            partials.len += 1;
        }
    }
    let sum = partials.as_slice().iter().sum();
    (sum, partials)
}

/// Fixed-capacity partial-product list (stack only).
#[derive(Debug, Clone, Copy)]
pub struct Partials {
    buf: [u32; 16],
    len: usize,
}

impl Partials {
    #[inline]
    pub fn as_slice(&self) -> &[u32] {
        &self.buf[..self.len]
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exhaustive_2bit() {
        let mut ev = Events::default();
        for a in 0..4u8 {
            for b in 0..4u8 {
                assert_eq!(mul2(a, b, &mut ev), a * b);
            }
        }
        assert_eq!(ev.mult2, 16);
    }

    #[test]
    fn int8_magnitude_path_exhaustive() {
        // all 8-bit magnitude pairs reproduce native multiplication
        let mut ev = Events::default();
        for a in (0..256u32).step_by(7) {
            for b in 0..256u32 {
                let (p, parts) = mul_mag(a, b, 4, &mut ev);
                assert_eq!(p, a * b, "{a}*{b}");
                assert_eq!(parts.len(), 16);
            }
        }
    }

    #[test]
    fn fp_mantissa_path_exhaustive() {
        // 4-bit x 4-bit (FP8/FP6 mantissas incl. implicit bit)
        let mut ev = Events::default();
        for a in 0..16u32 {
            for b in 0..16u32 {
                let (p, parts) = mul_mag(a, b, 2, &mut ev);
                assert_eq!(p, a * b);
                assert_eq!(parts.len(), 4);
            }
        }
        assert_eq!(ev.mult2, 16 * 16 * 4);
    }

    #[test]
    fn fp4_mantissa_path_exhaustive() {
        for a in 0..4u32 {
            for b in 0..4u32 {
                let mut ev = Events::default();
                let (p, parts) = mul_mag(a, b, 1, &mut ev);
                assert_eq!(p, a * b);
                assert_eq!(parts.len(), 1);
                assert_eq!(ev.mult2, 1);
            }
        }
    }
}
