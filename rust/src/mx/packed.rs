//! Sub-word-parallel packed MX tensors and SWAR GeMM kernels.
//!
//! The paper's first innovation is an arithmetic unit that exploits
//! **sub-word parallelism** across all six MX element formats. This
//! module is that idea executed in software: element codes stay
//! bit-packed in `u64` lanes (one lane = one 8-element tile row at the
//! format's natural width — 8/6/4 bits), dot products run over the
//! packed codes in integer sub-word arithmetic, and the per-block scale
//! is applied **once per 8×8 square block** instead of once per element
//! — exactly where MXDOTP-style designs find their throughput.
//!
//! ## Lane layout
//!
//! A [`PackedTensor`] stores the `Square8x8` block grid of an
//! [`MxTensor`]: per tile, 8 lanes (`u64`), lane `i` holding row `i`'s
//! eight codes at bits `j*w .. (j+1)*w` (LSB-first, `w =
//! ElementFormat::bits()`), plus one `i8` shared-exponent byte. INT8
//! tiles are therefore 64 bytes + 1 scale byte — the hardware's own
//! storage density — and the transpose is the block permutation the
//! paper builds its single-copy training storage on: one packed weight
//! image serves the forward GeMM and, via [`PackedTensor::transpose`],
//! both backward GeMMs.
//!
//! ## Value semantics and the bit-identity theorem
//!
//! Every kernel here computes the **block-ordered accumulation**
//! semantics of [`crate::util::mat::Mat::matmul_blocked`] with chunk =
//! 8: per output element, each 8-deep block-pair dot is evaluated
//! exactly, rounded to f32 once, and the f32 partials chain across
//! k-blocks. Fake-quantized MX values are integers times a per-block
//! power-of-two unit, so the in-block dot is computed in *integer*
//! sub-word arithmetic:
//!
//! * **MXINT8** — SWAR sign-extension of the 8 packed bytes into 16-bit
//!   lanes (borrow-isolated lane subtraction) and an 8-deep i32
//!   multiply-accumulate; exact, since |Σ| ≤ 8·127² < 2¹⁷.
//! * **MXFP4 (E2M1)** — a 16×16 nibble-pair product LUT in units of
//!   2⁻²; the packed nibbles index it directly.
//! * **MXFP8 E4M3 / MXFP6** — per-code integer mantissa LUTs in units
//!   of 2^(emin−mb), accumulated in i64 (≤ 2³⁹ dynamic range).
//! * **MXFP8 E5M2** — its 63-bit in-block product range exceeds exact
//!   i64 (and f64-chain exactness), so the packed kernel evaluates the
//!   same f64 chain as the dense kernel over a code-value LUT — equal
//!   by construction rather than by exactness.
//!
//! In every case the block partial is bitwise the one the dense
//! blocked kernel produces on the dequantized operands (the integer
//! sums sit well inside f64's 53-bit window, scales are exact powers of
//! two), so `packed_gemm` == `matmul_blocked` is a **theorem** the
//! tests assert with `==` on f32 bits — no tolerances anywhere
//! (`tests/packed.rs`, `tests/backend.rs`).

#![forbid(unsafe_code)]

use crate::mx::block::shared_exponent;
use crate::mx::element::{exp2i, ElementFormat};
use crate::mx::tensor::{Layout, MxTensor, SQ, SQ_ELEMS};
use crate::mx::ALL_ELEMENT_FORMATS;
use crate::util::mat::Mat;
use crate::util::par;
use std::sync::OnceLock;

// ------------------------------------------------------------------ SWAR

/// 16-bit lane masks over a u64 (4 lanes).
const LANE_LO: u64 = 0x00ff_00ff_00ff_00ff;
const LANE_BIAS: u64 = 0x0080_0080_0080_0080;
const LANE_TOP: u64 = 0x8000_8000_8000_8000;

/// Lane-wise 16-bit subtraction with borrow isolation (Hacker's
/// Delight §2-18): setting each lane's top bit before the full-width
/// subtract guarantees no borrow crosses a lane boundary; the XOR term
/// restores the true top bit per lane.
#[inline(always)]
fn swar_sub16(x: u64, y: u64) -> u64 {
    let d = (x | LANE_TOP).wrapping_sub(y & !LANE_TOP);
    d ^ ((x ^ !y) & LANE_TOP)
}

/// Sign-extend four packed bytes (at bits 0,16,32,48 of `x & LANE_LO`)
/// into 16-bit two's-complement lanes, all four in parallel: bias by
/// 0x80 per lane, then the borrow-isolated lane subtract undoes it with
/// the sign carried into the upper byte.
#[inline(always)]
fn swar_sext_bytes(x: u64) -> u64 {
    swar_sub16((x & LANE_LO) ^ LANE_BIAS, LANE_BIAS)
}

#[inline(always)]
fn lane16(x: u64, sh: u32) -> i32 {
    (x >> sh) as u16 as i16 as i32
}

/// Exact 8-deep dot product of two INT8 lanes (8 packed two's-complement
/// bytes each): SWAR sign-extension into sub-word 16-bit lanes, then
/// multiply-accumulate. |result| ≤ 8·128² — exact in i32.
#[inline(always)]
pub fn dot8_i8(a: u64, b: u64) -> i32 {
    let (ae, ao) = (swar_sext_bytes(a), swar_sext_bytes(a >> 8));
    let (be, bo) = (swar_sext_bytes(b), swar_sext_bytes(b >> 8));
    let mut s = 0i32;
    for sh in [0u32, 16, 32, 48] {
        s += lane16(ae, sh) * lane16(be, sh) + lane16(ao, sh) * lane16(bo, sh);
    }
    s
}

/// Scalar reference for [`dot8_i8`] — the oracle the SWAR kernel is
/// tested against (exhaustive boundary grids in the module tests).
pub fn dot8_i8_scalar(a: u64, b: u64) -> i32 {
    let (ab, bb) = (a.to_le_bytes(), b.to_le_bytes());
    let mut s = 0i32;
    for k in 0..SQ {
        s += (ab[k] as i8 as i32) * (bb[k] as i8 as i32);
    }
    s
}

/// In-register 8×8 byte-matrix transpose over 8 u64 row lanes: three
/// masked block-swap rounds (4×4-byte, 2×2-byte, 1×1-byte corners) —
/// the classic SWAR transpose, used to turn a packed INT8 tile's rows
/// into its columns without touching memory.
pub fn transpose8x8_bytes(t: &mut [u64; 8]) {
    // round 1: swap the off-diagonal 4x4-byte blocks
    const M4: u64 = 0x0000_0000_ffff_ffff;
    for i in 0..4 {
        let (u, v) = (t[i], t[i + 4]);
        t[i] = (u & M4) | ((v & M4) << 32);
        t[i + 4] = ((u >> 32) & M4) | (v & !M4);
    }
    // round 2: swap off-diagonal 2x2-byte blocks within each 4-row half
    const M2: u64 = 0x0000_ffff_0000_ffff;
    for g in [0usize, 4] {
        for i in g..g + 2 {
            let (u, v) = (t[i], t[i + 2]);
            t[i] = (u & M2) | ((v & M2) << 16);
            t[i + 2] = ((u >> 16) & M2) | (v & !M2);
        }
    }
    // round 3: swap off-diagonal single bytes within each 2-row pair
    const M1: u64 = 0x00ff_00ff_00ff_00ff;
    for g in [0usize, 2, 4, 6] {
        let (u, v) = (t[g], t[g + 1]);
        t[g] = (u & M1) | ((v & M1) << 8);
        t[g + 1] = ((u >> 8) & M1) | (v & !M1);
    }
}

// ------------------------------------------------------------------ LUTs

fn fmt_index(fmt: ElementFormat) -> usize {
    ALL_ELEMENT_FORMATS.iter().position(|f| *f == fmt).expect("one of the six")
}

static VAL_LUTS: [OnceLock<[f64; 256]>; 6] = [
    OnceLock::new(),
    OnceLock::new(),
    OnceLock::new(),
    OnceLock::new(),
    OnceLock::new(),
    OnceLock::new(),
];

/// Per-code decoded value (`ElementFormat::decode`), 256 entries.
fn val_lut(fmt: ElementFormat) -> &'static [f64; 256] {
    VAL_LUTS[fmt_index(fmt)].get_or_init(|| {
        let mut t = [0.0f64; 256];
        for (c, slot) in t.iter_mut().enumerate().take(fmt.code_count()) {
            *slot = fmt.decode(c as u8);
        }
        t
    })
}

static INT_LUTS: [OnceLock<[i32; 256]>; 6] = [
    OnceLock::new(),
    OnceLock::new(),
    OnceLock::new(),
    OnceLock::new(),
    OnceLock::new(),
    OnceLock::new(),
];

/// Per-code integer mantissa in units of 2^(emin−mb) — exact, because
/// every representable value (subnormals included) is an integer
/// multiple of that unit. Not built for E5M2 (57344·2¹⁶ overflows i32;
/// that format takes the f64 path) nor INT8 (codes *are* the integers).
fn int_lut(fmt: ElementFormat) -> &'static [i32; 256] {
    debug_assert!(!matches!(fmt, ElementFormat::E5M2 | ElementFormat::Int8));
    INT_LUTS[fmt_index(fmt)].get_or_init(|| {
        let unit = exp2i(fmt.emin() - fmt.mant_bits() as i32);
        let mut t = [0i32; 256];
        for (c, slot) in t.iter_mut().enumerate().take(fmt.code_count()) {
            *slot = (fmt.decode(c as u8) / unit) as i32;
        }
        t
    })
}

static E2M1_PAIR: OnceLock<[i32; 256]> = OnceLock::new();

/// 16×16 nibble-pair product LUT for E2M1 in units of 2⁻² — the INT4
/// sub-word path: a packed nibble pair indexes the product directly.
fn e2m1_pair_lut() -> &'static [i32; 256] {
    E2M1_PAIR.get_or_init(|| {
        let f = ElementFormat::E2M1;
        let mut t = [0i32; 256];
        for a in 0..16usize {
            for b in 0..16usize {
                t[(a << 4) | b] = (f.decode(a as u8) * f.decode(b as u8) * 4.0) as i32;
            }
        }
        t
    })
}

static E2M1_MANT: OnceLock<[i8; 16]> = OnceLock::new();

/// Per-code E2M1 integer mantissa in units of 2⁻¹ (`decode * 2` — every
/// E2M1 value is an integer multiple of one half, max |mantissa| 12).
/// A product of two such mantissas lands in units of 2⁻² — the same
/// unit [`e2m1_pair_lut`] uses — so a byte dot over these mantissas
/// equals the pair-LUT sum exactly. This is the 16-entry table the SIMD
/// nibble-shuffle kernels (`crate::mx::simd`) load into a vector
/// register; deriving it from `decode` keeps one source of truth.
pub(crate) fn e2m1_mant_lut16() -> &'static [i8; 16] {
    E2M1_MANT.get_or_init(|| {
        let f = ElementFormat::E2M1;
        let mut t = [0i8; 16];
        for (c, slot) in t.iter_mut().enumerate() {
            *slot = (f.decode(c as u8) * 2.0) as i8;
        }
        t
    })
}

/// Exponent of the per-block-pair product unit: the two operand scales
/// add to it, and the sum of one tile-pair dot is an exact integer in
/// this unit (0 marks the f64-path format, which carries no unit).
pub(crate) fn unit_exp(fmt: ElementFormat) -> i32 {
    match fmt {
        ElementFormat::Int8 => -12, // (2^-6)^2
        ElementFormat::E5M2 => 0,   // f64 chain, values carry their exponents
        _ => 2 * (fmt.emin() - fmt.mant_bits() as i32),
    }
}

#[inline(always)]
pub(crate) fn lane_code(lane: u64, j: usize, w: u32) -> usize {
    ((lane >> (j as u32 * w)) & ((1u64 << w) - 1)) as usize
}

// -------------------------------------------------------- packed tensor

/// Block count below which packing stays serial (mirrors
/// `mx::tensor`'s fork gate).
pub(crate) const PAR_MIN_BLOCKS: usize = 256;
/// Element count below which banded walks stay serial.
const PAR_MIN_ELEMS: usize = 1 << 15;

pub(crate) fn band_min_chunks(elems: usize, bands: usize) -> usize {
    if elems >= PAR_MIN_ELEMS {
        bands
    } else {
        usize::MAX
    }
}

/// A square-block MX tensor with its element codes bit-packed into u64
/// lanes — the storage the SWAR GeMM kernels execute on directly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedTensor {
    pub rows: usize,
    pub cols: usize,
    pub format: ElementFormat,
    /// 8×8 block grid dims.
    pub brows: usize,
    pub bcols: usize,
    /// Shared exponent per block, row-major block order.
    pub scales: Vec<i8>,
    /// 8 lanes per block (lane = tile row, codes at the format width,
    /// LSB-first), row-major block order.
    pub lanes: Vec<u64>,
}

impl PackedTensor {
    /// Bit-pack an already-quantized square tensor. Errors on vector
    /// layout (its transposed grouping has no single packed copy — the
    /// very storage cost the paper's square blocks remove).
    pub fn pack(q: &MxTensor) -> Result<PackedTensor, String> {
        if q.layout != Layout::Square8x8 {
            return Err(format!(
                "packed kernels run on square 8x8 blocks; got layout `{}`",
                q.layout.name()
            ));
        }
        let w = q.format.bits();
        let mut scales = Vec::with_capacity(q.blocks.len());
        let mut lanes = vec![0u64; q.blocks.len() * SQ];
        for (t, b) in q.blocks.iter().enumerate() {
            debug_assert_eq!(b.codes.len(), SQ_ELEMS);
            scales.push(b.scale_exp as i8);
            for i in 0..SQ {
                let mut lane = 0u64;
                for j in 0..SQ {
                    lane |= (b.codes[i * SQ + j] as u64) << (j as u32 * w);
                }
                lanes[t * SQ + i] = lane;
            }
        }
        Ok(PackedTensor {
            rows: q.rows,
            cols: q.cols,
            format: q.format,
            brows: q.brows,
            bcols: q.bcols,
            scales,
            lanes,
        })
    }

    /// Quantize a dense matrix straight into packed form — bit-identical
    /// codes and scales to `MxTensor::quantize(m, fmt, Square8x8)`
    /// followed by [`PackedTensor::pack`] (asserted by
    /// `tests/packed.rs`), without materializing the intermediate
    /// per-block `Vec<u8>`s.
    pub fn quantize_pack(m: &Mat, format: ElementFormat) -> PackedTensor {
        let brows = m.rows.div_ceil(SQ);
        let bcols = m.cols.div_ceil(SQ);
        let w = format.bits();
        let tiles = par::par_map(brows * bcols, PAR_MIN_BLOCKS, |t| {
            let (br, bc) = (t / bcols, t % bcols);
            let mut vals = [0.0f32; SQ_ELEMS];
            for i in 0..SQ {
                for j in 0..SQ {
                    let (r, c) = (br * SQ + i, bc * SQ + j);
                    if r < m.rows && c < m.cols {
                        vals[i * SQ + j] = m.at(r, c);
                    }
                }
            }
            let se = shared_exponent(&vals, format);
            let inv = exp2i(-se);
            let mut lanes = [0u64; SQ];
            for i in 0..SQ {
                for j in 0..SQ {
                    let code = format.encode(vals[i * SQ + j] as f64 * inv);
                    lanes[i] |= (code as u64) << (j as u32 * w);
                }
            }
            (se as i8, lanes)
        });
        let mut scales = Vec::with_capacity(tiles.len());
        let mut lanes = Vec::with_capacity(tiles.len() * SQ);
        for (se, tl) in tiles {
            scales.push(se);
            lanes.extend_from_slice(&tl);
        }
        PackedTensor { rows: m.rows, cols: m.cols, format, brows, bcols, scales, lanes }
    }

    /// The 8 lanes of block (br, bc).
    #[inline]
    pub fn tile(&self, br: usize, bc: usize) -> &[u64] {
        let t = (br * self.bcols + bc) * SQ;
        &self.lanes[t..t + SQ]
    }

    /// Shared exponent of block (br, bc).
    #[inline]
    pub fn scale_exp(&self, br: usize, bc: usize) -> i32 {
        self.scales[br * self.bcols + bc] as i32
    }

    /// Unpack back to the code-per-byte [`MxTensor`] form (bit-exact
    /// inverse of [`PackedTensor::pack`]).
    pub fn unpack(&self) -> MxTensor {
        use crate::mx::block::ScaledBlock;
        let w = self.format.bits();
        let mut blocks = Vec::with_capacity(self.brows * self.bcols);
        for t in 0..self.brows * self.bcols {
            let mut codes = vec![0u8; SQ_ELEMS];
            for i in 0..SQ {
                let lane = self.lanes[t * SQ + i];
                for j in 0..SQ {
                    codes[i * SQ + j] = lane_code(lane, j, w) as u8;
                }
            }
            blocks.push(ScaledBlock {
                scale_exp: self.scales[t] as i32,
                format: self.format,
                codes,
            });
        }
        MxTensor {
            rows: self.rows,
            cols: self.cols,
            format: self.format,
            layout: Layout::Square8x8,
            blocks,
            brows: self.brows,
            bcols: self.bcols,
        }
    }

    /// Dequantize to a dense matrix — bit-identical to
    /// `MxTensor::dequantize` of the unpacked tensor (same decode, same
    /// f64 scale multiply, same f32 rounding).
    pub fn dequantize(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        let (cols, w) = (self.cols, self.format.bits());
        let vals = val_lut(self.format);
        let min_chunks = band_min_chunks(self.rows * cols, self.brows);
        par::par_chunks_mut(&mut m.data, SQ * cols, min_chunks, |br, band| {
            let band_rows = if cols == 0 { 0 } else { band.len() / cols };
            for bc in 0..self.bcols {
                let tile = self.tile(br, bc);
                let scale = exp2i(self.scale_exp(br, bc));
                for (i, lane) in tile.iter().enumerate().take(band_rows) {
                    for j in 0..SQ {
                        let c = bc * SQ + j;
                        if c < cols {
                            band[i * cols + c] = (vals[lane_code(*lane, j, w)] * scale) as f32;
                        }
                    }
                }
            }
        });
        m
    }

    /// Transpose as a pure block permutation + in-register tile
    /// transpose — no requantization, no scale change: the paper's
    /// single-copy storage executed on the packed image. INT8 tiles use
    /// the SWAR byte-matrix transpose.
    pub fn transpose(&self) -> PackedTensor {
        let mut lanes = vec![0u64; self.lanes.len()];
        let mut scales = vec![0i8; self.scales.len()];
        for br in 0..self.brows {
            for bc in 0..self.bcols {
                let t = tile_transposed(self.tile(br, bc), self.format.bits());
                let dst = bc * self.brows + br;
                lanes[dst * SQ..(dst + 1) * SQ].copy_from_slice(&t);
                scales[dst] = self.scales[br * self.bcols + bc];
            }
        }
        PackedTensor {
            rows: self.cols,
            cols: self.rows,
            format: self.format,
            brows: self.bcols,
            bcols: self.brows,
            scales,
            lanes,
        }
    }

    /// Column sums of the dequantized matrix (bias gradients) without
    /// materializing it — f32 accumulation in the same (row-major)
    /// order as `Mat::col_sums`, so the result is bit-identical.
    pub fn col_sums(&self) -> Vec<f32> {
        let mut s = vec![0.0f32; self.cols];
        let (w, vals) = (self.format.bits(), val_lut(self.format));
        for r in 0..self.rows {
            let (br, i) = (r / SQ, r % SQ);
            for bc in 0..self.bcols {
                let lane = self.tile(br, bc)[i];
                let scale = exp2i(self.scale_exp(br, bc));
                for j in 0..SQ {
                    let c = bc * SQ + j;
                    if c < self.cols {
                        s[c] += (vals[lane_code(lane, j, w)] * scale) as f32;
                    }
                }
            }
        }
        s
    }

    /// Packed storage footprint in bytes (lanes + scale bytes).
    pub fn storage_bytes(&self) -> usize {
        self.lanes.len() * std::mem::size_of::<u64>() + self.scales.len()
    }

    /// FNV-1a checksum of one block: its lanes (little-endian) followed
    /// by its scale byte, so a single flipped code bit *or* a corrupted
    /// shared exponent changes the sum. This is the in-memory
    /// fault-detection substrate of the chaos layer
    /// ([`crate::chaos`]) — the same FNV-1a the shard index uses for
    /// at-rest chunks, applied per live block.
    pub fn block_checksum(&self, br: usize, bc: usize) -> u64 {
        let mut bytes = Vec::with_capacity(SQ * std::mem::size_of::<u64>() + 1);
        for lane in self.tile(br, bc) {
            bytes.extend_from_slice(&lane.to_le_bytes());
        }
        bytes.push(self.scales[br * self.bcols + bc] as u8);
        crate::util::bytes::fnv1a64(&bytes)
    }

    /// Per-block checksums in row-major block order. Optional and
    /// in-memory only (never serialized — the at-rest image is already
    /// covered by the store's chunk checksums); callers that want
    /// detection record these after quantization and verify before use.
    pub fn block_checksums(&self) -> Vec<u64> {
        let mut sums = Vec::with_capacity(self.brows * self.bcols);
        for br in 0..self.brows {
            for bc in 0..self.bcols {
                sums.push(self.block_checksum(br, bc));
            }
        }
        sums
    }

    /// Verify this tensor against checksums recorded earlier. Returns
    /// the `(brow, bcol)` of the first mismatching block — the exact
    /// fault site — or `Err` on a shape mismatch disguised as `(0, 0)`
    /// never: a recorded-length mismatch is its own error.
    pub fn verify_block_checksums(&self, recorded: &[u64]) -> Result<(), BlockCorruption> {
        if recorded.len() != self.brows * self.bcols {
            return Err(BlockCorruption::ShapeMismatch {
                recorded: recorded.len(),
                blocks: self.brows * self.bcols,
            });
        }
        for br in 0..self.brows {
            for bc in 0..self.bcols {
                if self.block_checksum(br, bc) != recorded[br * self.bcols + bc] {
                    return Err(BlockCorruption::Block { brow: br, bcol: bc });
                }
            }
        }
        Ok(())
    }
}

/// A failed [`PackedTensor::verify_block_checksums`]: either the first
/// corrupt block's coordinates, or a recorded-checksum list that does
/// not match the tensor's block grid at all.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockCorruption {
    /// Block `(brow, bcol)` no longer matches its recorded checksum.
    Block { brow: usize, bcol: usize },
    /// The recorded list covers a different block count than the tensor.
    ShapeMismatch { recorded: usize, blocks: usize },
}

impl std::fmt::Display for BlockCorruption {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BlockCorruption::Block { brow, bcol } => {
                write!(f, "packed block ({brow}, {bcol}) fails its checksum")
            }
            BlockCorruption::ShapeMismatch { recorded, blocks } => {
                write!(f, "{recorded} recorded checksums for {blocks} blocks")
            }
        }
    }
}

/// Transpose one tile's lanes (rows become columns). 8-bit codes take
/// the SWAR byte-matrix path; narrower widths repack through code
/// extraction.
pub(crate) fn tile_transposed(tile: &[u64], w: u32) -> [u64; SQ] {
    let mut t = [0u64; SQ];
    if w == u8::BITS {
        t.copy_from_slice(tile);
        transpose8x8_bytes(&mut t);
    } else {
        for (i, lane) in tile.iter().enumerate() {
            for j in 0..SQ {
                t[j] |= (lane_code(*lane, j, w) as u64) << (i as u32 * w);
            }
        }
    }
    t
}

// --------------------------------------------------------- dot kernels

/// One lane-pair (8-deep) dot, scaled to an f32 partial. `scale` is
/// `2^(sa + sb + unit_exp)` for the integer paths and `2^(sa + sb)` for
/// the E5M2 f64 path.
#[inline]
fn lane_partial(fmt: ElementFormat, a: u64, b: u64, scale: f64) -> f32 {
    match fmt {
        ElementFormat::Int8 => (dot8_i8(a, b) as f64 * scale) as f32,
        ElementFormat::E2M1 => {
            let (pair, w) = (e2m1_pair_lut(), fmt.bits());
            let mut s = 0i32;
            for k in 0..SQ {
                let idx = (lane_code(a, k, w) << w) | lane_code(b, k, w);
                s += pair[idx];
            }
            (s as f64 * scale) as f32
        }
        ElementFormat::E5M2 => {
            let (vals, w) = (val_lut(fmt), fmt.bits());
            let mut p = 0.0f64;
            for k in 0..SQ {
                p += vals[lane_code(a, k, w)] * vals[lane_code(b, k, w)];
            }
            (p * scale) as f32
        }
        _ => {
            let (lut, w) = (int_lut(fmt), fmt.bits());
            let mut s = 0i64;
            for k in 0..SQ {
                s += lut[lane_code(a, k, w)] as i64 * lut[lane_code(b, k, w)] as i64;
            }
            (s as f64 * scale) as f32
        }
    }
}

/// Accumulate one tile-pair's 64 scaled partials into `acc` (row-major
/// 8×8). `a` holds the left tile's rows; `bk` holds the right tile's
/// **k-major** lanes (its columns for a plain GeMM, its rows when the
/// right operand is consumed transposed).
fn tile_partials(fmt: ElementFormat, a: &[u64], bk: &[u64], scale: f64, acc: &mut [f32; 64]) {
    match fmt {
        ElementFormat::Int8 => {
            for i in 0..SQ {
                let al = a[i];
                for j in 0..SQ {
                    acc[i * SQ + j] += (dot8_i8(al, bk[j]) as f64 * scale) as f32;
                }
            }
        }
        ElementFormat::E2M1 => {
            let (pair, w) = (e2m1_pair_lut(), fmt.bits());
            for i in 0..SQ {
                let al = a[i];
                for j in 0..SQ {
                    let bl = bk[j];
                    let mut s = 0i32;
                    for k in 0..SQ {
                        s += pair[(lane_code(al, k, w) << w) | lane_code(bl, k, w)];
                    }
                    acc[i * SQ + j] += (s as f64 * scale) as f32;
                }
            }
        }
        ElementFormat::E5M2 => {
            let (vals, w) = (val_lut(fmt), fmt.bits());
            // pre-decode both tiles once; the chain itself must stay in
            // ascending-k order (f64 rounding order is the contract)
            let mut ad = [[0.0f64; SQ]; SQ];
            let mut bd = [[0.0f64; SQ]; SQ];
            for i in 0..SQ {
                for k in 0..SQ {
                    ad[i][k] = vals[lane_code(a[i], k, w)];
                    bd[i][k] = vals[lane_code(bk[i], k, w)];
                }
            }
            for i in 0..SQ {
                for j in 0..SQ {
                    let mut p = 0.0f64;
                    for k in 0..SQ {
                        p += ad[i][k] * bd[j][k];
                    }
                    acc[i * SQ + j] += (p * scale) as f32;
                }
            }
        }
        _ => {
            let (lut, w) = (int_lut(fmt), fmt.bits());
            let mut ad = [[0i64; SQ]; SQ];
            let mut bd = [[0i64; SQ]; SQ];
            for i in 0..SQ {
                for k in 0..SQ {
                    ad[i][k] = lut[lane_code(a[i], k, w)] as i64;
                    bd[i][k] = lut[lane_code(bk[i], k, w)] as i64;
                }
            }
            for i in 0..SQ {
                for j in 0..SQ {
                    let mut s = 0i64;
                    for k in 0..SQ {
                        s += ad[i][k] * bd[j][k];
                    }
                    acc[i * SQ + j] += (s as f64 * scale) as f32;
                }
            }
        }
    }
}

// -------------------------------------------------------------- GeMMs

/// `a @ b` over packed operands. The right operand's tiles are
/// transposed to k-major lanes once up front (O(n²), SWAR for INT8);
/// the O(n³) inner walk then runs register-tiled 8×8×8 block products
/// with one scale application per block pair. Parallel over 8-row
/// output bands, bit-identical to
/// `a.dequantize().matmul_blocked(&b.dequantize(), 8)`.
pub fn packed_gemm(a: &PackedTensor, b: &PackedTensor) -> Mat {
    assert_eq!(a.format, b.format, "format mismatch");
    assert_eq!(a.cols, b.rows, "inner dims mismatch");
    let fmt = a.format;
    let unit = unit_exp(fmt);
    // pre-transpose b's tiles so the inner loop reads k-major lanes
    let mut bt = vec![0u64; b.lanes.len()];
    for t in 0..b.brows * b.bcols {
        bt[t * SQ..(t + 1) * SQ].copy_from_slice(&tile_transposed(
            &b.lanes[t * SQ..(t + 1) * SQ],
            fmt.bits(),
        ));
    }
    let (m, n) = (a.rows, b.cols);
    let kb_n = a.bcols;
    debug_assert_eq!(kb_n, b.brows);
    let mut out = Mat::zeros(m, n);
    let min_chunks = band_min_chunks(m * n, a.brows);
    par::par_chunks_mut(&mut out.data, SQ * n, min_chunks, |bi, band| {
        let band_rows = if n == 0 { 0 } else { band.len() / n };
        for bj in 0..b.bcols {
            let mut acc = [0.0f32; SQ_ELEMS];
            for kb in 0..kb_n {
                let bk = &bt[(kb * b.bcols + bj) * SQ..(kb * b.bcols + bj + 1) * SQ];
                let se = a.scale_exp(bi, kb) + b.scale_exp(kb, bj) + unit;
                tile_partials(fmt, a.tile(bi, kb), bk, exp2i(se), &mut acc);
            }
            for i in 0..band_rows {
                for j in 0..SQ {
                    let c = bj * SQ + j;
                    if c < n {
                        band[i * n + c] = acc[i * SQ + j];
                    }
                }
            }
        }
    });
    out
}

/// `a @ bᵀ` over packed operands — the transposed consumption is
/// **free**: `b`'s row lanes already are the k-major lanes the tile
/// kernel wants, so no tile is transposed and no second copy exists
/// (the paper's backward-pass storage story, executed). Bit-identical
/// to `a.dequantize().matmul_blocked_nt(&b.dequantize(), 8)`.
pub fn packed_gemm_nt(a: &PackedTensor, b: &PackedTensor) -> Mat {
    assert_eq!(a.format, b.format, "format mismatch");
    assert_eq!(a.cols, b.cols, "inner dims mismatch");
    let fmt = a.format;
    let unit = unit_exp(fmt);
    let (m, n) = (a.rows, b.rows);
    let kb_n = a.bcols;
    debug_assert_eq!(kb_n, b.bcols);
    let mut out = Mat::zeros(m, n);
    let min_chunks = band_min_chunks(m * n, a.brows);
    par::par_chunks_mut(&mut out.data, SQ * n, min_chunks, |bi, band| {
        let band_rows = if n == 0 { 0 } else { band.len() / n };
        for bj in 0..b.brows {
            let mut acc = [0.0f32; SQ_ELEMS];
            for kb in 0..kb_n {
                let se = a.scale_exp(bi, kb) + b.scale_exp(bj, kb) + unit;
                tile_partials(fmt, a.tile(bi, kb), b.tile(bj, kb), exp2i(se), &mut acc);
            }
            for i in 0..band_rows {
                for j in 0..SQ {
                    let c = bj * SQ + j;
                    if c < n {
                        band[i * n + c] = acc[i * SQ + j];
                    }
                }
            }
        }
    });
    out
}

/// Single dot product `a[ar, :] · b[br, :]` over packed operands (one
/// output element of [`packed_gemm_nt`]) — the block-dot primitive,
/// exposed for tests and spot checks.
pub fn packed_dot(a: &PackedTensor, ar: usize, b: &PackedTensor, br: usize) -> f32 {
    assert_eq!(a.format, b.format, "format mismatch");
    assert_eq!(a.cols, b.cols, "inner dims mismatch");
    assert!(ar < a.rows && br < b.rows, "row out of range");
    let fmt = a.format;
    let unit = unit_exp(fmt);
    let mut s = 0.0f32;
    for kb in 0..a.bcols {
        let al = a.tile(ar / SQ, kb)[ar % SQ];
        let bl = b.tile(br / SQ, kb)[br % SQ];
        let se = a.scale_exp(ar / SQ, kb) + b.scale_exp(br / SQ, kb) + unit;
        s += lane_partial(fmt, al, bl, exp2i(se));
    }
    s
}

impl MxTensor {
    /// Bit-pack this (square-layout) tensor for the SWAR kernels.
    pub fn pack(&self) -> Result<PackedTensor, String> {
        PackedTensor::pack(self)
    }

    /// `self @ other` through the packed SWAR kernels (convenience:
    /// packs both operands; the backends hold [`PackedTensor`]s
    /// directly so packing amortizes over a whole training step).
    pub fn packed_gemm(&self, other: &MxTensor) -> Result<Mat, String> {
        Ok(crate::mx::packed::packed_gemm(&self.pack()?, &other.pack()?))
    }

    /// Row-dot `self[r, :] · other[o, :]` through the packed kernels.
    pub fn packed_dot(&self, r: usize, other: &MxTensor, o: usize) -> Result<f32, String> {
        Ok(crate::mx::packed::packed_dot(&self.pack()?, r, &other.pack()?, o))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Boundary byte values for the INT8 oracle grid: sign boundaries,
    /// lane-carry extremes, and the encoder's saturation points.
    const I8_BOUNDARY: [i8; 12] = [-128, -127, -65, -64, -63, -1, 0, 1, 63, 64, 126, 127];

    fn lane_of(bytes: [i8; 8]) -> u64 {
        let mut l = 0u64;
        for (k, b) in bytes.into_iter().enumerate() {
            l |= (b as u8 as u64) << (8 * k);
        }
        l
    }

    #[test]
    fn swar_sub16_isolates_lane_borrows() {
        // lanes that individually underflow must not borrow from their
        // neighbors; check every lane against scalar 16-bit arithmetic
        let cases = [0u16, 1, 0x7f, 0x80, 0xff, 0x100, 0x7fff, 0x8000, 0xffff];
        for &x0 in &cases {
            for &y0 in &cases {
                // place the interesting pair in each lane, surrounded by
                // maximally-borrowing neighbors
                for lane in 0..4 {
                    let mut x = 0u64;
                    let mut y = 0u64;
                    for l in 0..4 {
                        let (xv, yv) = if l == lane { (x0, y0) } else { (0u16, 0xffffu16) };
                        x |= (xv as u64) << (16 * l);
                        y |= (yv as u64) << (16 * l);
                    }
                    let got = swar_sub16(x, y);
                    for l in 0..4 {
                        let xl = (x >> (16 * l)) as u16;
                        let yl = (y >> (16 * l)) as u16;
                        let want = xl.wrapping_sub(yl);
                        assert_eq!(
                            (got >> (16 * l)) as u16,
                            want,
                            "lane {l}: {xl:#x} - {yl:#x}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn swar_sign_extension_matches_scalar_for_every_byte() {
        for v in 0..=255u8 {
            for k in 0..8usize {
                // neighbor lanes hold the worst carry generators
                let mut bytes = [[0x80u8; 8], [0x7f; 8], [0xff; 8]][k % 3];
                bytes[k] = v;
                let lane = u64::from_le_bytes(bytes);
                let (e, o) = (swar_sext_bytes(lane), swar_sext_bytes(lane >> 8));
                let src = if k % 2 == 0 { e } else { o };
                let got = lane16(src, 16 * (k as u32 / 2));
                assert_eq!(got, v as i8 as i32, "byte {v:#x} in lane {k}");
            }
        }
    }

    #[test]
    fn dot8_i8_matches_scalar_on_boundary_grid() {
        // every boundary pair, in every lane position, with the other
        // lanes alternating extreme values (lane-carry isolation)
        for &a in &I8_BOUNDARY {
            for &b in &I8_BOUNDARY {
                for k in 0..8usize {
                    let mut av = [127i8; 8];
                    let mut bv = [-128i8; 8];
                    av[(k + 3) % 8] = -128;
                    bv[(k + 5) % 8] = 127;
                    av[k] = a;
                    bv[k] = b;
                    let (la, lb) = (lane_of(av), lane_of(bv));
                    assert_eq!(
                        dot8_i8(la, lb),
                        dot8_i8_scalar(la, lb),
                        "a={a} b={b} lane {k}"
                    );
                }
            }
        }
    }

    #[test]
    fn dot8_i8_matches_scalar_on_random_lanes() {
        let mut rng = Pcg64::new(0x5A4);
        for _ in 0..20_000 {
            let (a, b) = (rng.next_u64(), rng.next_u64());
            assert_eq!(dot8_i8(a, b), dot8_i8_scalar(a, b), "a={a:#x} b={b:#x}");
        }
    }

    #[test]
    fn dot8_i8_accumulator_extremes_are_exact() {
        // the most positive and most negative exact sums: no i32
        // saturation or wraparound anywhere in the SWAR pipeline
        let all = |v: i8| lane_of([v; 8]);
        assert_eq!(dot8_i8(all(127), all(127)), 8 * 127 * 127);
        assert_eq!(dot8_i8(all(-128), all(-128)), 8 * 128 * 128);
        assert_eq!(dot8_i8(all(-128), all(127)), -8 * 128 * 127);
        assert_eq!(dot8_i8(all(127), all(-128)), -8 * 128 * 127);
        assert_eq!(dot8_i8(all(0), all(-128)), 0);
    }

    #[test]
    fn e2m1_pair_lut_exhaustive_against_decode_products() {
        // every INT4×INT4 (nibble) code pair — the full 16×16 table
        let f = ElementFormat::E2M1;
        let pair = e2m1_pair_lut();
        for a in 0..16u8 {
            for b in 0..16u8 {
                let want = f.decode(a) * f.decode(b) * 4.0;
                assert_eq!(pair[((a as usize) << 4) | b as usize] as f64, want, "{a:#x}x{b:#x}");
                assert_eq!(want.fract(), 0.0, "product not integral in 2^-2 units");
            }
        }
    }

    #[test]
    fn int_luts_are_exact_code_values() {
        let luttable = [
            ElementFormat::E4M3,
            ElementFormat::E3M2,
            ElementFormat::E2M3,
            ElementFormat::E2M1,
        ];
        for fmt in luttable {
            let lut = int_lut(fmt);
            let unit = exp2i(fmt.emin() - fmt.mant_bits() as i32);
            for c in 0..fmt.code_count() {
                if fmt.is_special(c as u8) {
                    continue; // E4M3 NaN codes: never emitted, not gated
                }
                let want = fmt.decode(c as u8);
                let got = lut[c] as f64 * unit;
                assert_eq!(got.to_bits(), want.to_bits(), "{fmt:?} code {c:#x}");
            }
        }
    }

    #[test]
    fn val_lut_matches_decode_for_all_formats() {
        for fmt in ALL_ELEMENT_FORMATS {
            let lut = val_lut(fmt);
            for c in 0..fmt.code_count() {
                let want = fmt.decode(c as u8);
                let got = lut[c];
                if want.is_nan() {
                    assert!(got.is_nan(), "{fmt:?} code {c:#x}");
                } else {
                    assert_eq!(got.to_bits(), want.to_bits(), "{fmt:?} code {c:#x}");
                }
            }
        }
    }

    #[test]
    fn swar_byte_transpose_matches_naive() {
        let mut rng = Pcg64::new(0x78A);
        for _ in 0..500 {
            let mut t = [0u64; 8];
            for l in t.iter_mut() {
                *l = rng.next_u64();
            }
            let mut got = t;
            transpose8x8_bytes(&mut got);
            for i in 0..8 {
                for j in 0..8 {
                    let want = (t[i] >> (8 * j)) as u8;
                    let have = (got[j] >> (8 * i)) as u8;
                    assert_eq!(have, want, "({i},{j})");
                }
            }
            // involution
            let mut back = got;
            transpose8x8_bytes(&mut back);
            assert_eq!(back, t);
        }
    }

    #[test]
    fn generic_tile_transpose_matches_swar_and_round_trips() {
        let mut rng = Pcg64::new(0x7A1);
        for fmt in ALL_ELEMENT_FORMATS {
            let w = fmt.bits();
            let mask = (1u64 << w) - 1;
            for _ in 0..200 {
                let mut tile = [0u64; 8];
                for l in tile.iter_mut() {
                    for j in 0..SQ {
                        *l |= (rng.next_u64() & mask) << (j as u32 * w);
                    }
                }
                let t = tile_transposed(&tile, w);
                for i in 0..SQ {
                    for j in 0..SQ {
                        assert_eq!(
                            lane_code(t[j], i, w),
                            lane_code(tile[i], j, w),
                            "{fmt:?} ({i},{j})"
                        );
                    }
                }
                let back = tile_transposed(&t, w);
                assert_eq!(back, tile, "{fmt:?} involution");
            }
        }
    }

    #[test]
    fn block_checksums_pin_every_lane_bit_and_scale_byte() {
        let mut rng = Pcg64::new(0xC45);
        for fmt in ALL_ELEMENT_FORMATS {
            let m = Mat::from_fn(20, 13, |_, _| rng.wide_f32().clamp(-1e6, 1e6));
            let p = PackedTensor::quantize_pack(&m, fmt);
            let sums = p.block_checksums();
            assert_eq!(sums.len(), p.brows * p.bcols);
            assert!(p.verify_block_checksums(&sums).is_ok(), "{fmt:?} clean tensor");

            // flip one code bit: exactly that block is named
            let mut flipped = p.clone();
            let t = rng.below((flipped.brows * flipped.bcols) as u64) as usize;
            let lane = t * SQ + rng.below(SQ as u64) as usize;
            flipped.lanes[lane] ^= 1u64 << rng.below(u64::BITS as u64 - 1);
            let err = flipped.verify_block_checksums(&sums).unwrap_err();
            assert_eq!(
                err,
                BlockCorruption::Block { brow: t / p.bcols, bcol: t % p.bcols },
                "{fmt:?}"
            );

            // flip a scale bit: the shared exponent is covered too
            let mut scaled = p.clone();
            scaled.scales[t] ^= 1;
            let err = scaled.verify_block_checksums(&sums).unwrap_err();
            assert_eq!(err, BlockCorruption::Block { brow: t / p.bcols, bcol: t % p.bcols });

            // wrong-length recording is a shape error, not a block blame
            assert!(matches!(
                p.verify_block_checksums(&sums[1..]),
                Err(BlockCorruption::ShapeMismatch { .. })
            ));
        }
    }

    #[test]
    fn lane_partial_matches_tile_partials() {
        // the single-lane primitive and the 8x8 tile kernel must agree
        // element for element (they share semantics, not code paths)
        let mut rng = Pcg64::new(0xD07);
        for fmt in ALL_ELEMENT_FORMATS {
            let m = Mat::from_fn(8, 8, |_, _| rng.wide_f32().clamp(-1e6, 1e6));
            let n = Mat::from_fn(8, 8, |_, _| rng.wide_f32().clamp(-1e6, 1e6));
            let pa = PackedTensor::quantize_pack(&m, fmt);
            let pb = PackedTensor::quantize_pack(&n, fmt);
            let unit = unit_exp(fmt);
            let se = pa.scale_exp(0, 0) + pb.scale_exp(0, 0) + unit;
            let mut acc = [0.0f32; SQ_ELEMS];
            tile_partials(fmt, pa.tile(0, 0), pb.tile(0, 0), exp2i(se), &mut acc);
            for i in 0..SQ {
                for j in 0..SQ {
                    let single = lane_partial(fmt, pa.tile(0, 0)[i], pb.tile(0, 0)[j], exp2i(se));
                    assert_eq!(acc[i * SQ + j].to_bits(), single.to_bits(), "{fmt:?} ({i},{j})");
                }
            }
        }
    }
}
