//! Block-quantized matrices: vector (OCP standard) vs square (the paper).
//!
//! The architectural point of the paper (§IV-A, Fig. 5): with row-vector
//! 32-element groups, quantizing `W` and `Wᵀ` yields *different* shared
//! exponents, so training hardware must either store two quantized copies
//! or requantize between passes. With 8×8 square groups the transpose of a
//! quantized tensor is a pure index permutation of the same blocks —
//! one stored copy serves forward (`x Wᵀ`-style) and backward (`e W`)
//! passes bit-identically. `MxTensor::transpose` implements exactly that,
//! and the test suite asserts the bit-identity claim.

#![forbid(unsafe_code)]

use crate::mx::block::{quantize_block, ScaledBlock};
use crate::mx::element::ElementFormat;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::mat::Mat;
use crate::util::par;

/// Block grouping scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Layout {
    /// OCP-standard 32-element row-vector blocks (Dacapo-style grouping).
    Vector32,
    /// The paper's 64-element (8×8) square blocks: two 32-element MX
    /// groups sharing one exponent — MX-standard compatible.
    Square8x8,
}

impl Layout {
    pub fn name(&self) -> &'static str {
        match self {
            Layout::Vector32 => "vector32",
            Layout::Square8x8 => "square8x8",
        }
    }
}

/// Square block edge (8) and element count (64).
pub const SQ: usize = 8;
pub const SQ_ELEMS: usize = SQ * SQ;
/// Vector block length (32).
pub const VEC: usize = 32;

/// A block-quantized matrix.
#[derive(Debug, Clone)]
pub struct MxTensor {
    pub rows: usize,
    pub cols: usize,
    pub format: ElementFormat,
    pub layout: Layout,
    /// Blocks in row-major block order. For `Square8x8`, block (br, bc)
    /// holds the 8×8 tile at (8br, 8bc) in row-major element order; for
    /// `Vector32`, block i holds 32 consecutive elements of a row
    /// (rows are padded up to a multiple of 32).
    pub blocks: Vec<ScaledBlock>,
    /// Block-grid dims.
    pub brows: usize,
    pub bcols: usize,
}

/// Block count below which quantization stays serial (fork-join costs
/// more than the work for small tensors).
const PAR_MIN_BLOCKS: usize = 256;

/// Element count below which the banded in-place paths stay serial:
/// band *count* alone is a bad proxy for work (a 64x8 matrix has 8
/// bands of trivial size), so the fork decision also requires enough
/// total elements to amortize thread spawn/join (~100us on Linux).
const PAR_MIN_ELEMS: usize = 1 << 15;

/// Minimum parallel chunk count for a banded walk over `elems` total
/// elements: the caller's band threshold when the matrix is large
/// enough to amortize forking, effectively-infinite (always serial)
/// otherwise.
fn band_min_chunks(elems: usize, bands: usize) -> usize {
    if elems >= PAR_MIN_ELEMS {
        bands
    } else {
        usize::MAX
    }
}

impl MxTensor {
    /// Quantize a dense matrix.
    ///
    /// Blocks share nothing but the read-only input (OCP MX §5.2), so
    /// large matrices fan the per-block work out over the parallel
    /// engine — bit-identical to [`MxTensor::quantize_serial`], which is
    /// asserted by `tests/parallel.rs`.
    pub fn quantize(m: &Mat, format: ElementFormat, layout: Layout) -> MxTensor {
        match layout {
            Layout::Square8x8 => {
                let brows = m.rows.div_ceil(SQ);
                let bcols = m.cols.div_ceil(SQ);
                let blocks = par::par_map(brows * bcols, PAR_MIN_BLOCKS, |t| {
                    let (br, bc) = (t / bcols, t % bcols);
                    let tile = m.block(br * SQ, bc * SQ, SQ, SQ);
                    quantize_block(&tile.data, format)
                });
                MxTensor { rows: m.rows, cols: m.cols, format, layout, blocks, brows, bcols }
            }
            Layout::Vector32 => {
                let bcols = m.cols.div_ceil(VEC);
                let brows = m.rows;
                let blocks = par::par_map(brows * bcols, PAR_MIN_BLOCKS, |t| {
                    let (r, bc) = (t / bcols, t % bcols);
                    let mut vals = [0.0f32; VEC];
                    for i in 0..VEC {
                        let c = bc * VEC + i;
                        if c < m.cols {
                            vals[i] = m.at(r, c);
                        }
                    }
                    quantize_block(&vals, format)
                });
                MxTensor { rows: m.rows, cols: m.cols, format, layout, blocks, brows, bcols }
            }
        }
    }

    /// Serial reference quantization — the loop the parallel path must
    /// match bit-for-bit (kept for identity tests and benchmarks).
    pub fn quantize_serial(m: &Mat, format: ElementFormat, layout: Layout) -> MxTensor {
        match layout {
            Layout::Square8x8 => {
                let brows = m.rows.div_ceil(SQ);
                let bcols = m.cols.div_ceil(SQ);
                let mut blocks = Vec::with_capacity(brows * bcols);
                for br in 0..brows {
                    for bc in 0..bcols {
                        let tile = m.block(br * SQ, bc * SQ, SQ, SQ);
                        blocks.push(quantize_block(&tile.data, format));
                    }
                }
                MxTensor { rows: m.rows, cols: m.cols, format, layout, blocks, brows, bcols }
            }
            Layout::Vector32 => {
                let bcols = m.cols.div_ceil(VEC);
                let brows = m.rows;
                let mut blocks = Vec::with_capacity(brows * bcols);
                for r in 0..m.rows {
                    for bc in 0..bcols {
                        let mut vals = [0.0f32; VEC];
                        for i in 0..VEC {
                            let c = bc * VEC + i;
                            if c < m.cols {
                                vals[i] = m.at(r, c);
                            }
                        }
                        blocks.push(quantize_block(&vals, format));
                    }
                }
                MxTensor { rows: m.rows, cols: m.cols, format, layout, blocks, brows, bcols }
            }
        }
    }

    /// Dequantize back to a dense matrix.
    ///
    /// Parallel over row bands (each band owns a disjoint slice of the
    /// output), bit-identical to [`MxTensor::dequantize_serial`].
    pub fn dequantize(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        let cols = self.cols;
        match self.layout {
            Layout::Square8x8 => {
                let min_chunks = band_min_chunks(self.rows * cols, 8);
                par::par_chunks_mut(&mut m.data, SQ * cols, min_chunks, |br, band| {
                    let band_rows = if cols == 0 { 0 } else { band.len() / cols };
                    for bc in 0..self.bcols {
                        let b = &self.blocks[br * self.bcols + bc];
                        for i in 0..band_rows {
                            for j in 0..SQ {
                                let c = bc * SQ + j;
                                if c < cols {
                                    band[i * cols + c] = b.decode(i * SQ + j) as f32;
                                }
                            }
                        }
                    }
                });
            }
            Layout::Vector32 => {
                let min_chunks = band_min_chunks(self.rows * cols, 64);
                par::par_chunks_mut(&mut m.data, cols, min_chunks, |r, row| {
                    for bc in 0..self.bcols {
                        let b = &self.blocks[r * self.bcols + bc];
                        for i in 0..VEC {
                            let c = bc * VEC + i;
                            if c < cols {
                                row[c] = b.decode(i) as f32;
                            }
                        }
                    }
                });
            }
        }
        m
    }

    /// Serial reference dequantization (identity-test twin of
    /// [`MxTensor::dequantize`]).
    pub fn dequantize_serial(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        match self.layout {
            Layout::Square8x8 => {
                for br in 0..self.brows {
                    for bc in 0..self.bcols {
                        let b = &self.blocks[br * self.bcols + bc];
                        for i in 0..SQ {
                            for j in 0..SQ {
                                let (r, c) = (br * SQ + i, bc * SQ + j);
                                if r < self.rows && c < self.cols {
                                    *m.at_mut(r, c) = b.decode(i * SQ + j) as f32;
                                }
                            }
                        }
                    }
                }
            }
            Layout::Vector32 => {
                for r in 0..self.rows {
                    for bc in 0..self.bcols {
                        let b = &self.blocks[r * self.bcols + bc];
                        for i in 0..VEC {
                            let c = bc * VEC + i;
                            if c < self.cols {
                                *m.at_mut(r, c) = b.decode(i) as f32;
                            }
                        }
                    }
                }
            }
        }
        m
    }

    /// Transpose **without requantization** — only possible for square
    /// layout (the paper's storage contribution). Pure permutation: block
    /// (br,bc) moves to (bc,br) and each 8×8 tile is transposed in place;
    /// shared exponents are untouched, so dequantized values are
    /// bit-identical to transposing the dequantized matrix.
    ///
    /// Returns `None` for vector layout, where the transposed grouping
    /// crosses block boundaries and a requantization (or second stored
    /// copy) is unavoidable — exactly the Dacapo inefficiency.
    pub fn transpose(&self) -> Option<MxTensor> {
        if self.layout != Layout::Square8x8 {
            return None;
        }
        let mut blocks = Vec::with_capacity(self.blocks.len());
        for bc in 0..self.bcols {
            for br in 0..self.brows {
                let b = &self.blocks[br * self.bcols + bc];
                let mut codes = vec![0u8; SQ_ELEMS];
                for i in 0..SQ {
                    for j in 0..SQ {
                        codes[j * SQ + i] = b.codes[i * SQ + j];
                    }
                }
                blocks.push(ScaledBlock { scale_exp: b.scale_exp, format: b.format, codes });
            }
        }
        Some(MxTensor {
            rows: self.cols,
            cols: self.rows,
            format: self.format,
            layout: self.layout,
            blocks,
            brows: self.bcols,
            bcols: self.brows,
        })
    }

    /// Total storage in bits (elements + shared exponents), counting the
    /// padded block grid exactly as the hardware stores it.
    pub fn storage_bits(&self) -> usize {
        self.blocks.iter().map(|b| b.storage_bits()).sum()
    }

    /// Storage in KiB.
    pub fn storage_kib(&self) -> f64 {
        self.storage_bits() as f64 / 8.0 / 1024.0
    }

    /// Fake-quantize a dense matrix through this layout/format (QAT).
    pub fn fake_quant(m: &Mat, format: ElementFormat, layout: Layout) -> Mat {
        MxTensor::quantize(m, format, layout).dequantize()
    }

    /// Fetch the 8×8 tile (block) at block coords — square layout only.
    pub fn square_block(&self, br: usize, bc: usize) -> &ScaledBlock {
        assert_eq!(self.layout, Layout::Square8x8);
        &self.blocks[br * self.bcols + bc]
    }

    /// Elements stored per block in this layout (including padding).
    fn block_elems(layout: Layout) -> usize {
        match layout {
            Layout::Square8x8 => SQ_ELEMS,
            Layout::Vector32 => VEC,
        }
    }

    /// Serialize exactly as the hardware stores the tensor: a small
    /// header, one scale byte per block, then the element codes
    /// bit-packed at the format's width (8/6/4 bits). This is the MX
    /// checkpoint payload — square tensors are written **once** and
    /// serve both passes after load (the transpose stays a free block
    /// permutation), the paper's single-copy storage on disk.
    pub fn write_bytes(&self, w: &mut ByteWriter) {
        w.put_u8(match self.layout {
            Layout::Vector32 => 0,
            Layout::Square8x8 => 1,
        });
        let fmt_idx = crate::mx::ALL_ELEMENT_FORMATS
            .iter()
            .position(|f| *f == self.format)
            .expect("format is one of the six");
        w.put_u8(fmt_idx as u8);
        w.put_u32(self.rows as u32);
        w.put_u32(self.cols as u32);
        for b in &self.blocks {
            w.put_i8(b.scale_exp as i8);
        }
        let bits = self.format.bits();
        w.put_packed(self.blocks.iter().flat_map(|b| b.codes.iter().copied()), bits);
    }

    /// Inverse of [`MxTensor::write_bytes`] — bit-exact: scales, codes,
    /// and the block grid come back identical (`tests/checkpoint.rs`).
    pub fn read_bytes(r: &mut ByteReader<'_>) -> Result<MxTensor, String> {
        let layout = match r.get_u8()? {
            0 => Layout::Vector32,
            1 => Layout::Square8x8,
            t => return Err(format!("unknown MxTensor layout tag {t}")),
        };
        let fmt_idx = r.get_u8()? as usize;
        let format = *crate::mx::ALL_ELEMENT_FORMATS
            .get(fmt_idx)
            .ok_or_else(|| format!("unknown element-format index {fmt_idx}"))?;
        let rows = r.get_u32()? as usize;
        let cols = r.get_u32()? as usize;
        let (brows, bcols) = match layout {
            Layout::Square8x8 => (rows.div_ceil(SQ), cols.div_ceil(SQ)),
            Layout::Vector32 => (rows, cols.div_ceil(VEC)),
        };
        let n_blocks = brows
            .checked_mul(bcols)
            .ok_or_else(|| format!("block grid overflow ({rows}x{cols})"))?;
        // every block needs at least its scale byte — reject corrupt
        // headers before allocating for them
        if n_blocks > r.remaining() {
            return Err(format!("{n_blocks} blocks exceed the {} bytes left", r.remaining()));
        }
        let mut scales = Vec::with_capacity(n_blocks);
        for _ in 0..n_blocks {
            scales.push(r.get_i8()? as i32);
        }
        let elems = Self::block_elems(layout);
        let codes = r.get_packed(n_blocks * elems, format.bits())?;
        let blocks = scales
            .into_iter()
            .zip(codes.chunks_exact(elems))
            .map(|(scale_exp, c)| ScaledBlock { scale_exp, format, codes: c.to_vec() })
            .collect();
        Ok(MxTensor { rows, cols, format, layout, blocks, brows, bcols })
    }

    /// [`MxTensor::write_bytes`] into a fresh buffer.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        self.write_bytes(&mut w);
        w.into_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::ALL_ELEMENT_FORMATS;
    use crate::util::rng::Pcg64;

    fn wide_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        Mat::from_fn(rows, cols, |_, _| rng.wide_f32().clamp(-1e6, 1e6))
    }

    #[test]
    fn square_transpose_is_bit_identical() {
        // THE paper claim: quantize-then-transpose == transpose-then-quantize
        // for square blocks (no requantization needed).
        for fmt in ALL_ELEMENT_FORMATS {
            let m = wide_mat(24, 16, 7 + fmt.bits() as u64);
            let q = MxTensor::quantize(&m, fmt, Layout::Square8x8);
            let qt = q.transpose().unwrap();
            let direct = MxTensor::quantize(&m.transpose(), fmt, Layout::Square8x8);
            assert_eq!(qt.dequantize(), direct.dequantize(), "{fmt:?}");
            // and it equals the transpose of the dequantized original
            assert_eq!(qt.dequantize(), q.dequantize().transpose(), "{fmt:?}");
        }
    }

    #[test]
    fn vector_transpose_requires_requantization() {
        // The Dacapo problem: row-vector grouping of Wᵀ differs from W.
        // Rows with distinct per-row scales quantize well row-wise, but
        // transposed rows (original columns) mix all scales.
        let mut rng = Pcg64::new(99);
        let m = Mat::from_fn(32, 32, |r, _| rng.normal_f32() * ((r % 7) as f32 - 3.0).exp2());
        let q = MxTensor::quantize(&m, ElementFormat::Int8, Layout::Vector32);
        assert!(q.transpose().is_none());
        let qt = MxTensor::quantize(&m.transpose(), ElementFormat::Int8, Layout::Vector32);
        // quantizing the transpose gives *different* values than
        // transposing the quantized matrix (different shared exponents)
        let a = q.dequantize().transpose();
        let b = qt.dequantize();
        assert_ne!(a.data, b.data, "wide-dynamic-range matrix must quantize differently");
    }

    #[test]
    fn roundtrip_error_small_for_gaussian_data() {
        let mut rng = Pcg64::new(3);
        let m = Mat::randn(64, 64, 1.0, &mut rng);
        for fmt in ALL_ELEMENT_FORMATS {
            for layout in [Layout::Vector32, Layout::Square8x8] {
                let deq = MxTensor::fake_quant(&m, fmt, layout);
                let rel = (deq.mse(&m).sqrt()) / (m.fro_norm() as f64 / 64.0);
                // coarsest format (E2M1) should still be within ~25% RMS
                assert!(rel < 0.25, "{fmt:?} {layout:?}: rel RMS {rel}");
            }
        }
    }

    #[test]
    fn square_beats_vector_on_locally_scaled_data() {
        // Data whose magnitude varies per 8x8 tile: square grouping tracks
        // it; 32-wide row vectors straddle tiles and lose precision.
        let mut rng = Pcg64::new(4);
        let m = Mat::from_fn(32, 32, |r, c| {
            let tile_scale = ((r / 8 + c / 8) as f32 * 4.0).exp2();
            rng.normal_f32() * tile_scale
        });
        let sq = MxTensor::fake_quant(&m, ElementFormat::Int8, Layout::Square8x8);
        let vec = MxTensor::fake_quant(&m, ElementFormat::Int8, Layout::Vector32);
        // compare per-tile *relative* error (absolute MSE is dominated by
        // the largest-scale tiles, where both groupings coincide)
        let rel_err = |q: &Mat| -> f64 {
            let mut total = 0.0;
            for br in 0..4 {
                for bc in 0..4 {
                    let t = m.block(br * 8, bc * 8, 8, 8);
                    let tq = q.block(br * 8, bc * 8, 8, 8);
                    let scale = t.max_abs().max(1e-30) as f64;
                    total += tq.mse(&t) / (scale * scale);
                }
            }
            total
        };
        assert!(rel_err(&sq) < rel_err(&vec), "square {} vs vector {}", rel_err(&sq), rel_err(&vec));
    }

    #[test]
    fn storage_accounting_8x8_vs_vector() {
        // 256x256 INT8: square = 1024 blocks * (8 + 64*8) bits;
        // vector = 256 rows * 8 blocks * (8 + 32*8) bits.
        let m = Mat::zeros(256, 256);
        let sq = MxTensor::quantize(&m, ElementFormat::Int8, Layout::Square8x8);
        let vec = MxTensor::quantize(&m, ElementFormat::Int8, Layout::Vector32);
        assert_eq!(sq.storage_bits(), 1024 * (8 + 64 * 8));
        assert_eq!(vec.storage_bits(), 256 * 8 * (8 + 32 * 8));
        assert!(sq.storage_bits() < vec.storage_bits());
    }

    #[test]
    fn padding_tiles_roundtrip() {
        // Non-multiple-of-8 dims: padded region must not corrupt values.
        let m = wide_mat(13, 21, 11);
        for layout in [Layout::Vector32, Layout::Square8x8] {
            let q = MxTensor::quantize(&m, ElementFormat::E4M3, layout);
            let d = q.dequantize();
            assert_eq!((d.rows, d.cols), (13, 21));
            // error bounded by format resolution relative to tile max
            assert!(d.mse(&m) < m.max_abs() as f64 * m.max_abs() as f64 * 0.01);
        }
    }

    #[test]
    fn byte_serialization_is_bit_exact_and_dense() {
        for fmt in ALL_ELEMENT_FORMATS {
            for layout in [Layout::Square8x8, Layout::Vector32] {
                let m = wide_mat(13, 21, 0x5E1 + fmt.bits() as u64);
                let q = MxTensor::quantize(&m, fmt, layout);
                let bytes = q.to_bytes();
                // header (10) + 1 scale byte/block + packed codes
                let elems = q.blocks.len()
                    * match layout {
                        Layout::Square8x8 => SQ_ELEMS,
                        Layout::Vector32 => VEC,
                    };
                let expect =
                    10 + q.blocks.len() + (elems * fmt.bits() as usize).div_ceil(8);
                assert_eq!(bytes.len(), expect, "{fmt:?} {layout:?} density");
                let mut r = crate::util::bytes::ByteReader::new(&bytes);
                let q2 = MxTensor::read_bytes(&mut r).unwrap();
                assert_eq!(r.remaining(), 0);
                assert_eq!(q2.blocks, q.blocks, "{fmt:?} {layout:?}");
                let shape = |t: &MxTensor| (t.rows, t.cols, t.brows, t.bcols);
                assert_eq!(shape(&q2), shape(&q));
                assert_eq!(q2.dequantize().data, q.dequantize().data);
            }
        }
    }

    #[test]
    fn byte_deserialization_rejects_garbage() {
        let m = wide_mat(8, 8, 3);
        let q = MxTensor::quantize(&m, ElementFormat::Int8, Layout::Square8x8);
        let bytes = q.to_bytes();
        // truncation
        let mut r = crate::util::bytes::ByteReader::new(&bytes[..bytes.len() / 2]);
        assert!(MxTensor::read_bytes(&mut r).is_err());
        // bad layout tag
        let mut bad = bytes.clone();
        bad[0] = 9;
        assert!(MxTensor::read_bytes(&mut crate::util::bytes::ByteReader::new(&bad)).is_err());
        // bad format index
        let mut bad = bytes;
        bad[1] = 200;
        assert!(MxTensor::read_bytes(&mut crate::util::bytes::ByteReader::new(&bad)).is_err());
    }

    #[test]
    fn square_block_is_two_mx_groups() {
        // MX-standard compatibility: 64 elements = 2 x 32-element groups
        // sharing one exponent (paper §IV-A).
        assert_eq!(SQ_ELEMS, 2 * VEC);
    }
}

/// Fast in-place fake-quantization of a dense matrix (QAT hot path) —
/// same values as `MxTensor::fake_quant`, no tensor materialization.
///
/// §Parallel: blocks are independent, so the work fans out over row
/// bands (square layout: 8-row bands; vector layout: single rows), each
/// band owning a disjoint slice of the output. Bit-identical to
/// [`fake_quant_mat_fast_serial`] (asserted by `tests/parallel.rs`).
pub fn fake_quant_mat_fast(m: &Mat, format: ElementFormat, layout: Layout) -> Mat {
    let mut out = Mat::zeros(0, 0);
    fake_quant_mat_fast_into(m, format, layout, &mut out);
    out
}

/// [`fake_quant_mat_fast`] writing into a caller-owned buffer: `out` is
/// reshaped to `m`'s dims, reusing its allocation when capacity allows.
/// This is the zero-allocation steady state of the QAT backends' per-
/// layer scratch buffers (`backend::FakeQuantBackend`) — after the first
/// training step no quant call allocates.
pub fn fake_quant_mat_fast_into(m: &Mat, format: ElementFormat, layout: Layout, out: &mut Mat) {
    use crate::mx::block::fake_quant_block_fast;
    out.rows = m.rows;
    out.cols = m.cols;
    out.data.clear();
    out.data.resize(m.rows * m.cols, 0.0);
    let cols = m.cols;
    match layout {
        Layout::Square8x8 => {
            let bcols = m.cols.div_ceil(SQ);
            let min_chunks = band_min_chunks(m.rows * cols, 8);
            par::par_chunks_mut(&mut out.data, SQ * cols, min_chunks, |br, band| {
                let band_rows = if cols == 0 { 0 } else { band.len() / cols };
                let r0 = br * SQ;
                let mut buf = [0.0f32; SQ_ELEMS];
                for bc in 0..bcols {
                    let c0 = bc * SQ;
                    for i in 0..SQ {
                        for j in 0..SQ {
                            let (r, c) = (r0 + i, c0 + j);
                            buf[i * SQ + j] = if r < m.rows && c < m.cols { m.at(r, c) } else { 0.0 };
                        }
                    }
                    fake_quant_block_fast(&mut buf, format);
                    for i in 0..band_rows {
                        for j in 0..SQ {
                            let c = c0 + j;
                            if c < cols {
                                band[i * cols + c] = buf[i * SQ + j];
                            }
                        }
                    }
                }
            });
        }
        Layout::Vector32 => {
            let bcols = m.cols.div_ceil(VEC);
            let min_chunks = band_min_chunks(m.rows * cols, 64);
            par::par_chunks_mut(&mut out.data, cols, min_chunks, |r, row| {
                let mut buf = [0.0f32; VEC];
                for bc in 0..bcols {
                    let c0 = bc * VEC;
                    for i in 0..VEC {
                        let c = c0 + i;
                        buf[i] = if c < m.cols { m.at(r, c) } else { 0.0 };
                    }
                    fake_quant_block_fast(&mut buf, format);
                    for i in 0..VEC {
                        let c = c0 + i;
                        if c < cols {
                            row[c] = buf[i];
                        }
                    }
                }
            });
        }
    }
}

/// Serial reference of [`fake_quant_mat_fast`] (identity-test twin and
/// the benchmark baseline).
pub fn fake_quant_mat_fast_serial(m: &Mat, format: ElementFormat, layout: Layout) -> Mat {
    use crate::mx::block::fake_quant_block_fast;
    let mut out = m.clone();
    match layout {
        Layout::Square8x8 => {
            let brows = m.rows.div_ceil(SQ);
            let bcols = m.cols.div_ceil(SQ);
            let mut buf = [0.0f32; SQ_ELEMS];
            for br in 0..brows {
                for bc in 0..bcols {
                    let (r0, c0) = (br * SQ, bc * SQ);
                    for i in 0..SQ {
                        for j in 0..SQ {
                            let (r, c) = (r0 + i, c0 + j);
                            buf[i * SQ + j] = if r < m.rows && c < m.cols { m.at(r, c) } else { 0.0 };
                        }
                    }
                    fake_quant_block_fast(&mut buf, format);
                    for i in 0..SQ {
                        for j in 0..SQ {
                            let (r, c) = (r0 + i, c0 + j);
                            if r < m.rows && c < m.cols {
                                *out.at_mut(r, c) = buf[i * SQ + j];
                            }
                        }
                    }
                }
            }
        }
        Layout::Vector32 => {
            let bcols = m.cols.div_ceil(VEC);
            let mut buf = [0.0f32; VEC];
            for r in 0..m.rows {
                for bc in 0..bcols {
                    let c0 = bc * VEC;
                    for i in 0..VEC {
                        let c = c0 + i;
                        buf[i] = if c < m.cols { m.at(r, c) } else { 0.0 };
                    }
                    fake_quant_block_fast(&mut buf, format);
                    for i in 0..VEC {
                        let c = c0 + i;
                        if c < m.cols {
                            *out.at_mut(r, c) = buf[i];
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod fast_path_tests {
    use super::*;
    use crate::mx::ALL_ELEMENT_FORMATS;
    use crate::util::rng::Pcg64;
    use crate::util::testing::forall;

    #[test]
    fn fast_fake_quant_matches_codec_path() {
        // the perf-pass contract: bit-identical to quantize->dequantize
        forall(
            0xFA57,
            64,
            |r: &mut Pcg64| {
                let fmt = ALL_ELEMENT_FORMATS[r.below(6) as usize];
                let rows = 8 + r.below(25) as usize;
                let cols = 8 + r.below(25) as usize;
                let mut m = Mat::zeros(rows, cols);
                for v in m.data.iter_mut() {
                    *v = r.wide_f32().clamp(-1e20, 1e20);
                }
                (fmt, m)
            },
            |(fmt, m)| {
                for layout in [Layout::Square8x8, Layout::Vector32] {
                    let slow = MxTensor::fake_quant(m, *fmt, layout);
                    let fast = fake_quant_mat_fast(m, *fmt, layout);
                    if slow.data != fast.data {
                        let idx = slow.data.iter().zip(&fast.data).position(|(a, b)| a != b).unwrap();
                        return Err(format!(
                            "{fmt:?} {layout:?} elem {idx}: slow {} fast {} (input {})",
                            slow.data[idx], fast.data[idx], m.data[idx]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fake_quant_into_reuses_buffer_bit_identically() {
        // compare against the untouched *serial* twin, which did not go
        // through the zero-fill `_into` rewrite — a genuinely
        // independent reference (the dirty reused buffer must never
        // leak stale values into any element)
        let mut rng = Pcg64::new(0x1770);
        let mut out = Mat::from_fn(64, 64, |_, _| f32::NAN); // poisoned scratch
        for (rows, cols) in [(16, 16), (13, 21), (8, 40), (5, 5)] {
            let m = Mat::from_fn(rows, cols, |_, _| rng.wide_f32().clamp(-1e6, 1e6));
            for layout in [Layout::Square8x8, Layout::Vector32] {
                for fmt in [ElementFormat::Int8, ElementFormat::E2M1] {
                    fake_quant_mat_fast_into(&m, fmt, layout, &mut out);
                    let golden = fake_quant_mat_fast_serial(&m, fmt, layout);
                    assert_eq!((out.rows, out.cols), (rows, cols));
                    assert_eq!(out.data, golden.data, "{fmt:?} {layout:?} {rows}x{cols}");
                }
            }
        }
    }
}
