//! Microscaling (MX) data formats — bit-exact codecs.
//!
//! Implements the six concrete formats of the OCP MX v1.0 standard used by
//! the paper (Table I): MXINT8, MXFP8 E5M2, MXFP8 E4M3, MXFP6 E3M2,
//! MXFP6 E2M3, MXFP4 E2M1 — plus the paper's two block-grouping schemes
//! (32-element vectors per the standard, 64-element 8x8 squares per the
//! paper's §IV-A contribution) and the Dacapo MX9/MX6/MX4 baseline format
//! (shared microexponents, ISCA'23) used for every comparison.

pub mod ablation;
pub mod block;
pub mod dacapo;
pub mod element;
pub mod packed;
pub mod simd;
pub mod tensor;

pub use block::{quantize_block, ScaledBlock, SCALE_EMIN, SCALE_EMAX};
pub use dacapo::{DacapoFormat, DacapoTensor};
pub use element::ElementFormat;
pub use packed::{packed_dot, packed_gemm, packed_gemm_nt, PackedTensor};
pub use tensor::{Layout, MxTensor};

/// A complete MX configuration: element format + block grouping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MxFormat {
    pub element: ElementFormat,
    pub layout: Layout,
}

impl MxFormat {
    /// The paper's configuration: the given element format over 8x8
    /// square shared-exponent blocks.
    pub const fn square(element: ElementFormat) -> Self {
        Self { element, layout: Layout::Square8x8 }
    }

    /// The OCP-standard configuration: 32-element row-vector blocks.
    pub const fn vector(element: ElementFormat) -> Self {
        Self { element, layout: Layout::Vector32 }
    }

    /// Average storage bits per element including the amortized shared
    /// exponent (8 bits over the block size).
    pub fn bits_per_element(&self) -> f64 {
        let shared = 8.0
            / match self.layout {
                Layout::Vector32 => 32.0,
                Layout::Square8x8 => 64.0,
            };
        self.element.bits() as f64 + shared
    }
}

/// All six standard element formats, in the paper's Table I order.
pub const ALL_ELEMENT_FORMATS: [ElementFormat; 6] = [
    ElementFormat::Int8,
    ElementFormat::E5M2,
    ElementFormat::E4M3,
    ElementFormat::E3M2,
    ElementFormat::E2M3,
    ElementFormat::E2M1,
];
