//! Dacapo's MX9 / MX6 / MX4 baseline formats (shared microexponents).
//!
//! Dacapo (ISCA'24) implements the precursor MX format of Rouhani et al.,
//! "With Shared Microexponents, a Little Shifting Goes a Long Way"
//! (ISCA'23), *not* the OCP standard (paper §V-C):
//!
//! * 16-element vector blocks with an 8-bit shared exponent (level 1);
//! * a 1-bit micro-exponent per 2-element subgroup (level 2), giving
//!   subgroups whose local max is small one extra binade of precision;
//! * sign-magnitude element payloads of 1+7 / 1+4 / 1+2 bits for
//!   MX9 / MX6 / MX4 (9/6/4 bits per element average incl. the shared
//!   fields: 8/16 + 1/2 + payload).
//!
//! Value of element `i`: `(-1)^s * m / 2^mant_bits * 2^(E - D_i)` where
//! `E` is the block's shared exponent and `D_i ∈ {0,1}` its subgroup's
//! micro-exponent.

#![forbid(unsafe_code)]

use crate::mx::block::{SCALE_EMAX, SCALE_EMIN};
use crate::mx::element::{exp2i, floor_log2, rne};
use crate::util::mat::Mat;

/// Dacapo block size and subgroup size (ISCA'23 BDR paper, Dacapo config).
pub const DACAPO_BLOCK: usize = 16;
pub const DACAPO_SUBGROUP: usize = 2;

/// MX9 / MX6 / MX4.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DacapoFormat {
    Mx9,
    Mx6,
    Mx4,
}

impl DacapoFormat {
    /// Sign-magnitude mantissa bits of the element payload.
    pub const fn mant_bits(&self) -> u32 {
        match self {
            DacapoFormat::Mx9 => 7,
            DacapoFormat::Mx6 => 4,
            DacapoFormat::Mx4 => 2,
        }
    }

    /// Average bits per element: payload + 1/2 (micro-exp) + 8/16 (shared).
    pub fn bits_per_element(&self) -> f64 {
        (1 + self.mant_bits()) as f64 + 0.5 + 8.0 / DACAPO_BLOCK as f64
    }

    /// Bits per element counting only the payload (sign + mantissa).
    pub const fn payload_bits(&self) -> u32 {
        1 + self.mant_bits()
    }

    pub fn name(&self) -> &'static str {
        match self {
            DacapoFormat::Mx9 => "mx9",
            DacapoFormat::Mx6 => "mx6",
            DacapoFormat::Mx4 => "mx4",
        }
    }

    /// The corresponding format of ours under iso-bit comparison
    /// (paper Table IV rows: MXINT8 vs MX9, MXFP8/6 vs MX6, MXFP4 vs MX4).
    pub fn ours_equivalent(&self) -> crate::mx::ElementFormat {
        use crate::mx::ElementFormat as E;
        match self {
            DacapoFormat::Mx9 => E::Int8,
            DacapoFormat::Mx6 => E::E4M3,
            DacapoFormat::Mx4 => E::E2M1,
        }
    }
}

/// One quantized Dacapo block.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DacapoBlock {
    /// Shared exponent E (power of two of the block max's binade).
    pub shared_exp: i32,
    /// Per-subgroup 1-bit micro-exponents D_i (len = 8 for block of 16).
    pub micro: Vec<u8>,
    /// Sign-magnitude payloads: (sign, magnitude).
    pub codes: Vec<(u8, u8)>,
    pub format: DacapoFormat,
}

impl DacapoBlock {
    pub fn decode(&self, i: usize) -> f64 {
        let (s, m) = self.codes[i];
        let d = self.micro[i / DACAPO_SUBGROUP] as i32;
        let sign = if s == 1 { -1.0 } else { 1.0 };
        let frac = m as f64 / exp2i(self.format.mant_bits() as i32);
        sign * frac * exp2i(self.shared_exp - d)
    }

    pub fn dequantize(&self) -> Vec<f64> {
        (0..self.codes.len()).map(|i| self.decode(i)).collect()
    }

    /// Stored bits: 8 shared + 1/subgroup + payload/element.
    pub fn storage_bits(&self) -> usize {
        8 + self.micro.len() + self.codes.len() * self.format.payload_bits() as usize
    }
}

/// Quantize 16 values into a Dacapo block.
///
/// Shared exponent: binade *above* the block max so that all fractions are
/// in [-1, 1) (BFP convention: `E = floor(log2(max)) + 1`). Each 2-element
/// subgroup sets `D=1` (one extra precision bit) iff its own max fits in
/// half the block range.
pub fn quantize_dacapo_block(values: &[f32], format: DacapoFormat) -> DacapoBlock {
    assert_eq!(values.len(), DACAPO_BLOCK);
    let max_abs = values.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let shared_exp = if max_abs == 0.0 {
        SCALE_EMIN
    } else {
        // exact binade extraction (see mx::element::floor_log2 §Audit)
        (floor_log2(max_abs as f64) + 1).clamp(SCALE_EMIN, SCALE_EMAX)
    };
    let mant = format.mant_bits() as i32;
    let grid = exp2i(mant); // 2^mant steps per unit fraction
    let n_sub = DACAPO_BLOCK / DACAPO_SUBGROUP;
    let mut micro = vec![0u8; n_sub];
    for (g, m) in micro.iter_mut().enumerate() {
        let sub = &values[g * DACAPO_SUBGROUP..(g + 1) * DACAPO_SUBGROUP];
        let sub_max = sub.iter().fold(0.0f32, |mm, &v| mm.max(v.abs()));
        // subgroup fits in the lower binade -> shift up one bit
        if sub_max as f64 <= exp2i(shared_exp - 1) * (1.0 - 0.5 / grid) {
            *m = 1;
        }
    }
    let max_mag = (grid - 1.0) as u8 as f64; // saturate at 2^mant - 1
    let codes = values
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            let d = micro[i / DACAPO_SUBGROUP] as i32;
            let frac = v as f64 / exp2i(shared_exp - d);
            let q = rne(frac.abs() * grid).min(max_mag);
            ((v < 0.0) as u8, q as u8)
        })
        .collect();
    DacapoBlock { shared_exp, micro, codes, format }
}

/// A Dacapo-quantized matrix: row-vector 16-element blocks.
#[derive(Debug, Clone)]
pub struct DacapoTensor {
    pub rows: usize,
    pub cols: usize,
    pub format: DacapoFormat,
    pub blocks: Vec<DacapoBlock>,
    pub bcols: usize,
}

impl DacapoTensor {
    pub fn quantize(m: &Mat, format: DacapoFormat) -> DacapoTensor {
        let bcols = m.cols.div_ceil(DACAPO_BLOCK);
        let mut blocks = Vec::with_capacity(m.rows * bcols);
        for r in 0..m.rows {
            for bc in 0..bcols {
                let mut vals = [0.0f32; DACAPO_BLOCK];
                for i in 0..DACAPO_BLOCK {
                    let c = bc * DACAPO_BLOCK + i;
                    if c < m.cols {
                        vals[i] = m.at(r, c);
                    }
                }
                blocks.push(quantize_dacapo_block(&vals, format));
            }
        }
        DacapoTensor { rows: m.rows, cols: m.cols, format, blocks, bcols }
    }

    pub fn dequantize(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for bc in 0..self.bcols {
                let b = &self.blocks[r * self.bcols + bc];
                for i in 0..DACAPO_BLOCK {
                    let c = bc * DACAPO_BLOCK + i;
                    if c < self.cols {
                        *m.at_mut(r, c) = b.decode(i) as f32;
                    }
                }
            }
        }
        m
    }

    pub fn storage_bits(&self) -> usize {
        self.blocks.iter().map(|b| b.storage_bits()).sum()
    }

    pub fn storage_kib(&self) -> f64 {
        self.storage_bits() as f64 / 8.0 / 1024.0
    }

    /// Fake-quantize through Dacapo's format (for training comparisons).
    pub fn fake_quant(m: &Mat, format: DacapoFormat) -> Mat {
        DacapoTensor::quantize(m, format).dequantize()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::util::testing::forall;

    #[test]
    fn bits_per_element_match_names() {
        assert_eq!(DacapoFormat::Mx9.bits_per_element(), 9.0);
        assert_eq!(DacapoFormat::Mx6.bits_per_element(), 6.0);
        assert_eq!(DacapoFormat::Mx4.bits_per_element(), 4.0);
    }

    #[test]
    fn decode_respects_micro_exponent() {
        // construct data where one subgroup is far smaller than the max
        let mut v = [0.0f32; 16];
        v[0] = 1.0;
        v[8] = 0.01;
        v[9] = 0.02;
        let b = quantize_dacapo_block(&v, DacapoFormat::Mx9);
        assert_eq!(b.micro[0], 0, "max subgroup has D=0");
        assert_eq!(b.micro[4], 1, "small subgroup gets the extra bit");
        // the small values are represented more precisely than without micro
        let err_with = (b.decode(8) - 0.01).abs();
        assert!(err_with <= exp2i(b.shared_exp - 1) / 128.0);
    }

    #[test]
    fn roundtrip_error_bounded() {
        forall(
            0xDAC,
            256,
            |r| {
                let mut v = [0.0f32; 16];
                for x in v.iter_mut() {
                    *x = r.normal_f32() * 2.0;
                }
                v
            },
            |v| {
                let b = quantize_dacapo_block(v, DacapoFormat::Mx9);
                let scale = exp2i(b.shared_exp);
                for i in 0..16 {
                    let err = (b.decode(i) - v[i] as f64).abs();
                    // half a step at the element's effective grid, plus
                    // saturation slack of one step
                    let tol = scale / 128.0 * 1.5;
                    if err > tol {
                        return Err(format!("elem {i}: {} err {err} > {tol}", v[i]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn mx4_coarser_than_mx9() {
        let mut rng = Pcg64::new(5);
        let m = Mat::randn(32, 32, 1.0, &mut rng);
        let e9 = DacapoTensor::fake_quant(&m, DacapoFormat::Mx9).mse(&m);
        let e4 = DacapoTensor::fake_quant(&m, DacapoFormat::Mx4).mse(&m);
        assert!(e9 < e4);
        assert!(e9 < 1e-4);
    }

    #[test]
    fn storage_bits_9_per_element() {
        let m = Mat::zeros(16, 256);
        let t = DacapoTensor::quantize(&m, DacapoFormat::Mx9);
        // 16 rows * 16 blocks * (8 + 8 + 16*8) bits = exactly 9 bits/elem
        assert_eq!(t.storage_bits(), 16 * 16 * (8 + 8 + 16 * 8));
        assert_eq!(t.storage_bits() as f64 / (16.0 * 256.0), 9.0);
    }

    #[test]
    fn transposed_quantization_differs_vector_grouping() {
        // Dacapo's vector grouping: W and Wᵀ quantize differently -> the
        // two-copies problem (Table III). Needs data whose dynamic range
        // varies within rows.
        let mut rng = Pcg64::new(6);
        let m = Mat::from_fn(32, 32, |r, _| rng.normal_f32() * ((r % 7) as f32 - 3.0).exp2());
        let w = DacapoTensor::fake_quant(&m, DacapoFormat::Mx9);
        let wt = DacapoTensor::fake_quant(&m.transpose(), DacapoFormat::Mx9).transpose();
        assert_ne!(w.data, wt.data);
    }

    #[test]
    fn zero_block_roundtrips() {
        let b = quantize_dacapo_block(&[0.0; 16], DacapoFormat::Mx6);
        assert!(b.dequantize().iter().all(|&x| x == 0.0));
    }
}
