//! Arch-native SIMD kernel paths for the packed MX pipeline.
//!
//! The packed SWAR kernels ([`crate::mx::packed`]) are the portable,
//! `forbid(unsafe_code)` oracle. This module lifts the three hot
//! primitives — the 8×8×8 tile dot, the E2M1 nibble-LUT decode, and
//! the INT8 tile quantizer — onto `std::arch` AVX2 / SSE4.1 (x86-64)
//! and NEON (aarch64) vectors, under three invariants:
//!
//! 1. **Bit-identity.** Every SIMD leg produces the same bits as its
//!    SWAR twin (`<name>_swar` in this file): the integer tile dots
//!    are exact in both worlds, and the drivers below chain the scaled
//!    f32 partials in the *same block order* as `packed_gemm`, so the
//!    bit-identity theorem of `mx::packed` extends unchanged.
//! 2. **Dispatch safety.** `#[target_feature]` functions are reached
//!    only through the guard arms here, which re-check the one-time
//!    runtime snapshot ([`detect::features`]) immediately before each
//!    `unsafe` call. A path that is unavailable at runtime silently
//!    degrades to the SWAR twin — the registry
//!    ([`crate::backend::KernelRegistry`]) additionally refuses to
//!    *construct* with a forced-unavailable path, so the degradation
//!    arm is defense in depth, not a reachable policy.
//! 3. **Scope.** SIMD legs exist for the formats where sub-word
//!    parallelism pays ([`SIMD_FORMATS`]: INT8 and E2M1 — the 8-bit
//!    and 4-bit ends of Table I); the four mid-width float formats
//!    take the SWAR path under every [`KernelPath`].

use crate::mx::block::shared_exponent_from_max;
use crate::mx::element::{exp2i, ElementFormat};
use crate::mx::packed::{
    band_min_chunks, e2m1_mant_lut16, lane_code, packed_gemm, packed_gemm_nt, unit_exp,
    PackedTensor, PAR_MIN_BLOCKS,
};
use crate::mx::tensor::{SQ, SQ_ELEMS};
use crate::util::mat::Mat;
use crate::util::par;

pub mod detect;
#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

use detect::CpuFeatures;

/// The formats with dedicated SIMD decode/dot legs. Everything else
/// resolves to SWAR regardless of path.
pub const SIMD_FORMATS: [ElementFormat; 2] = [ElementFormat::Int8, ElementFormat::E2M1];

/// One resolvable kernel implementation family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPath {
    /// Portable u64 sub-word kernels — always available, the oracle.
    Swar,
    /// x86-64 SSE4.1 (128-bit lanes).
    Sse41,
    /// x86-64 AVX2 (256-bit lanes).
    Avx2,
    /// AArch64 Advanced SIMD.
    Neon,
}

impl KernelPath {
    /// Every path, fallback first.
    pub const ALL: [KernelPath; 4] =
        [KernelPath::Swar, KernelPath::Sse41, KernelPath::Avx2, KernelPath::Neon];

    /// Canonical lowercase name (the `MXSCALE_KERNEL` / `--kernel`
    /// vocabulary, and the string stamped into bench provenance).
    pub fn name(&self) -> &'static str {
        match self {
            KernelPath::Swar => "swar",
            KernelPath::Sse41 => "sse41",
            KernelPath::Avx2 => "avx2",
            KernelPath::Neon => "neon",
        }
    }

    /// Parse a user-supplied path name.
    pub fn parse(s: &str) -> Result<KernelPath, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "swar" => Ok(KernelPath::Swar),
            "sse41" | "sse4.1" => Ok(KernelPath::Sse41),
            "avx2" => Ok(KernelPath::Avx2),
            "neon" => Ok(KernelPath::Neon),
            other => Err(format!(
                "unknown kernel path `{other}` (expected one of: swar, sse41, avx2, neon)"
            )),
        }
    }

    /// Whether this path can run on a CPU with the given features.
    pub fn available(&self, f: CpuFeatures) -> bool {
        match self {
            KernelPath::Swar => true,
            KernelPath::Sse41 => f.sse41,
            KernelPath::Avx2 => f.avx2,
            KernelPath::Neon => f.neon,
        }
    }
}

// ------------------------------------------------------------ SWAR twins
//
// The scalar/SWAR twins of every SIMD kernel, in the exact operand
// convention the vector legs use. These are the oracles `tests/simd.rs`
// pins each leg against (lint rule L8 requires the reference), and the
// bodies every dispatcher falls back to.

/// 8×8×8 i8 tile dot, scalar: `dots[i*8+j] = Σₖ a_dec[i*8+k] ·
/// b_dec[k*8+j]` — `a_dec` row-major, `b_dec` k-major. Exact in i32.
pub fn tile_dots_i8_swar(
    a_dec: &[i8; SQ_ELEMS],
    b_dec: &[i8; SQ_ELEMS],
    dots: &mut [i32; SQ_ELEMS],
) {
    for i in 0..SQ {
        for j in 0..SQ {
            let mut s = 0i32;
            for k in 0..SQ {
                s += a_dec[i * SQ + k] as i32 * b_dec[k * SQ + j] as i32;
            }
            dots[i * SQ + j] = s;
        }
    }
}

/// E2M1 tile decode, scalar: packed nibbles → integer mantissas in
/// units of 2⁻¹ ([`e2m1_mant_lut16`]), row-major.
pub fn decode_tile_e2m1_swar(lanes: &[u64; SQ], out: &mut [i8; SQ_ELEMS]) {
    let lut = e2m1_mant_lut16();
    for (i, lane) in lanes.iter().enumerate() {
        for j in 0..SQ {
            out[i * SQ + j] = lut[lane_code(*lane, j, 4)];
        }
    }
}

/// 8×8 i8 transpose, scalar.
pub fn transpose8x8_i8_swar(x: &[i8; SQ_ELEMS], out: &mut [i8; SQ_ELEMS]) {
    for i in 0..SQ {
        for j in 0..SQ {
            out[j * SQ + i] = x[i * SQ + j];
        }
    }
}

/// Max-|v| over a gathered tile, scalar — the exact fold
/// `shared_exponent` performs (NaN entries are skipped, the
/// accumulator is never NaN).
pub fn max_abs_swar(vals: &[f32; SQ_ELEMS]) -> f32 {
    vals.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// INT8 tile quantizer, scalar: the same `encode` loop
/// `PackedTensor::quantize_pack` runs, over one gathered tile.
pub fn quantize_tile_int8_swar(vals: &[f32; SQ_ELEMS], se: i32, lanes: &mut [u64; SQ]) {
    let inv = exp2i(-se);
    for (i, lane) in lanes.iter_mut().enumerate() {
        *lane = 0;
        for j in 0..SQ {
            let code = ElementFormat::Int8.encode(vals[i * SQ + j] as f64 * inv);
            *lane |= (code as u64) << (j as u32 * 8);
        }
    }
}

// ----------------------------------------------------------- dispatchers
//
// Each dispatcher re-checks the runtime feature snapshot in its guard
// before entering the `unsafe` call — the availability check and the
// call are adjacent by construction, which is the entire dispatch-
// safety argument (DESIGN.md §10). Unavailable or foreign-arch paths
// fall through to the SWAR twin.

pub(crate) fn tile_dots_i8(
    path: KernelPath,
    a_dec: &[i8; SQ_ELEMS],
    b_dec: &[i8; SQ_ELEMS],
    dots: &mut [i32; SQ_ELEMS],
) {
    match path {
        #[cfg(target_arch = "x86_64")]
        KernelPath::Avx2 if detect::features().avx2 => {
            // SAFETY: AVX2 presence confirmed from the runtime snapshot
            // in the guard on the line above.
            unsafe { x86::tile_dots_i8_avx2(a_dec, b_dec, dots) }
        }
        #[cfg(target_arch = "x86_64")]
        KernelPath::Sse41 if detect::features().sse41 => {
            // SAFETY: SSE4.1 presence confirmed from the runtime
            // snapshot in the guard on the line above.
            unsafe { x86::tile_dots_i8_sse41(a_dec, b_dec, dots) }
        }
        #[cfg(target_arch = "aarch64")]
        KernelPath::Neon if detect::features().neon => {
            // SAFETY: NEON presence confirmed from the runtime snapshot
            // in the guard on the line above.
            unsafe { neon::tile_dots_i8_neon(a_dec, b_dec, dots) }
        }
        _ => tile_dots_i8_swar(a_dec, b_dec, dots),
    }
}

pub(crate) fn decode_tile_e2m1(path: KernelPath, lanes: &[u64; SQ], out: &mut [i8; SQ_ELEMS]) {
    match path {
        #[cfg(target_arch = "x86_64")]
        KernelPath::Avx2 if detect::features().avx2 => {
            // SAFETY: AVX2 presence confirmed from the runtime snapshot
            // in the guard on the line above.
            unsafe { x86::decode_tile_e2m1_avx2(lanes, out) }
        }
        #[cfg(target_arch = "x86_64")]
        KernelPath::Sse41 if detect::features().sse41 => {
            // SAFETY: SSE4.1 presence confirmed from the runtime
            // snapshot in the guard on the line above.
            unsafe { x86::decode_tile_e2m1_sse41(lanes, out) }
        }
        #[cfg(target_arch = "aarch64")]
        KernelPath::Neon if detect::features().neon => {
            // SAFETY: NEON presence confirmed from the runtime snapshot
            // in the guard on the line above.
            unsafe { neon::decode_tile_e2m1_neon(lanes, out) }
        }
        _ => decode_tile_e2m1_swar(lanes, out),
    }
}

pub(crate) fn transpose8x8_i8(path: KernelPath, x: &[i8; SQ_ELEMS], out: &mut [i8; SQ_ELEMS]) {
    match path {
        // SSE2 is x86-64 baseline: any vector path may use it, no gate
        #[cfg(target_arch = "x86_64")]
        KernelPath::Avx2 | KernelPath::Sse41 => x86::transpose8x8_i8_sse2(x, out),
        _ => transpose8x8_i8_swar(x, out),
    }
}

pub(crate) fn max_abs(path: KernelPath, vals: &[f32; SQ_ELEMS]) -> f32 {
    match path {
        #[cfg(target_arch = "x86_64")]
        KernelPath::Avx2 if detect::features().avx2 => {
            // SAFETY: AVX2 presence confirmed from the runtime snapshot
            // in the guard on the line above.
            unsafe { x86::max_abs_avx2(vals) }
        }
        #[cfg(target_arch = "x86_64")]
        KernelPath::Sse41 if detect::features().sse41 => {
            // SAFETY: SSE4.1 presence confirmed from the runtime
            // snapshot in the guard on the line above.
            unsafe { x86::max_abs_sse41(vals) }
        }
        _ => max_abs_swar(vals),
    }
}

pub(crate) fn quantize_tile_int8(
    path: KernelPath,
    vals: &[f32; SQ_ELEMS],
    se: i32,
    lanes: &mut [u64; SQ],
) {
    match path {
        #[cfg(target_arch = "x86_64")]
        KernelPath::Avx2 if detect::features().avx2 => {
            // SAFETY: AVX2 presence confirmed from the runtime snapshot
            // in the guard on the line above.
            unsafe { x86::quantize_tile_int8_avx2(vals, se, lanes) }
        }
        #[cfg(target_arch = "x86_64")]
        KernelPath::Sse41 if detect::features().sse41 => {
            // SAFETY: SSE4.1 presence confirmed from the runtime
            // snapshot in the guard on the line above.
            unsafe { x86::quantize_tile_int8_sse41(vals, se, lanes) }
        }
        _ => quantize_tile_int8_swar(vals, se, lanes),
    }
}

// --------------------------------------------------------------- drivers

/// Decode one packed tile to row-major i8 mantissas for the i8 tile
/// dot: INT8 codes are copied (lane bytes *are* the two's-complement
/// values), E2M1 nibbles go through the mantissa LUT. Only called for
/// [`SIMD_FORMATS`].
fn decode_tile(path: KernelPath, fmt: ElementFormat, tile: &[u64], out: &mut [i8; SQ_ELEMS]) {
    match fmt {
        ElementFormat::Int8 => {
            for (i, lane) in tile.iter().enumerate() {
                for (j, byte) in lane.to_le_bytes().iter().enumerate() {
                    out[i * SQ + j] = *byte as i8;
                }
            }
        }
        _ => {
            debug_assert_eq!(fmt, ElementFormat::E2M1);
            let mut lt = [0u64; SQ];
            lt.copy_from_slice(tile);
            decode_tile_e2m1(path, &lt, out);
        }
    }
}

/// `a @ b` on the given kernel path — bit-identical to
/// [`packed_gemm`] (which it delegates to for SWAR and the non-SIMD
/// formats). The right operand's natural row lanes already are the
/// k-major layout the tile dot consumes, so unlike the SWAR kernel no
/// tile transpose happens here: decode replaces it.
pub fn gemm(path: KernelPath, a: &PackedTensor, b: &PackedTensor) -> Mat {
    if path == KernelPath::Swar || !SIMD_FORMATS.contains(&a.format) {
        return packed_gemm(a, b);
    }
    assert_eq!(a.format, b.format, "format mismatch");
    assert_eq!(a.cols, b.rows, "inner dims mismatch");
    let fmt = a.format;
    let unit = unit_exp(fmt);
    // pre-decode every b tile once (k-major: natural packed rows)
    let mut bdec = vec![[0i8; SQ_ELEMS]; b.brows * b.bcols];
    for (t, dt) in bdec.iter_mut().enumerate() {
        decode_tile(path, fmt, &b.lanes[t * SQ..(t + 1) * SQ], dt);
    }
    let (m, n) = (a.rows, b.cols);
    let kb_n = a.bcols;
    debug_assert_eq!(kb_n, b.brows);
    let mut out = Mat::zeros(m, n);
    let min_chunks = band_min_chunks(m * n, a.brows);
    par::par_chunks_mut(&mut out.data, SQ * n, min_chunks, |bi, band| {
        let band_rows = if n == 0 { 0 } else { band.len() / n };
        let mut adec = vec![[0i8; SQ_ELEMS]; kb_n];
        for (kb, dt) in adec.iter_mut().enumerate() {
            decode_tile(path, fmt, a.tile(bi, kb), dt);
        }
        let mut dots = [0i32; SQ_ELEMS];
        for bj in 0..b.bcols {
            let mut acc = [0.0f32; SQ_ELEMS];
            for kb in 0..kb_n {
                let se = a.scale_exp(bi, kb) + b.scale_exp(kb, bj) + unit;
                let scale = exp2i(se);
                tile_dots_i8(path, &adec[kb], &bdec[kb * b.bcols + bj], &mut dots);
                // row-major accumulation — the same per-element f32
                // chain order as the SWAR tile_partials
                for (s, d) in acc.iter_mut().zip(dots.iter()) {
                    *s += (*d as f64 * scale) as f32;
                }
            }
            for i in 0..band_rows {
                for j in 0..SQ {
                    let c = bj * SQ + j;
                    if c < n {
                        band[i * n + c] = acc[i * SQ + j];
                    }
                }
            }
        }
    });
    out
}

/// `a @ bᵀ` on the given kernel path — bit-identical to
/// [`packed_gemm_nt`]. Here the decode *does* transpose each right
/// tile (8×8 i8 unpack ladder) to recover k-major order, mirroring
/// how the SWAR nt-kernel gets its transposed consumption for free.
pub fn gemm_nt(path: KernelPath, a: &PackedTensor, b: &PackedTensor) -> Mat {
    if path == KernelPath::Swar || !SIMD_FORMATS.contains(&a.format) {
        return packed_gemm_nt(a, b);
    }
    assert_eq!(a.format, b.format, "format mismatch");
    assert_eq!(a.cols, b.cols, "inner dims mismatch");
    let fmt = a.format;
    let unit = unit_exp(fmt);
    // decode + transpose every b tile once (row-major -> k-major)
    let mut bdec = vec![[0i8; SQ_ELEMS]; b.brows * b.bcols];
    let mut tmp = [0i8; SQ_ELEMS];
    for (t, dt) in bdec.iter_mut().enumerate() {
        decode_tile(path, fmt, &b.lanes[t * SQ..(t + 1) * SQ], &mut tmp);
        transpose8x8_i8(path, &tmp, dt);
    }
    let (m, n) = (a.rows, b.rows);
    let kb_n = a.bcols;
    debug_assert_eq!(kb_n, b.bcols);
    let mut out = Mat::zeros(m, n);
    let min_chunks = band_min_chunks(m * n, a.brows);
    par::par_chunks_mut(&mut out.data, SQ * n, min_chunks, |bi, band| {
        let band_rows = if n == 0 { 0 } else { band.len() / n };
        let mut adec = vec![[0i8; SQ_ELEMS]; kb_n];
        for (kb, dt) in adec.iter_mut().enumerate() {
            decode_tile(path, fmt, a.tile(bi, kb), dt);
        }
        let mut dots = [0i32; SQ_ELEMS];
        for bj in 0..b.brows {
            let mut acc = [0.0f32; SQ_ELEMS];
            for kb in 0..kb_n {
                let se = a.scale_exp(bi, kb) + b.scale_exp(bj, kb) + unit;
                let scale = exp2i(se);
                tile_dots_i8(path, &adec[kb], &bdec[bj * b.bcols + kb], &mut dots);
                for (s, d) in acc.iter_mut().zip(dots.iter()) {
                    *s += (*d as f64 * scale) as f32;
                }
            }
            for i in 0..band_rows {
                for j in 0..SQ {
                    let c = bj * SQ + j;
                    if c < n {
                        band[i * n + c] = acc[i * SQ + j];
                    }
                }
            }
        }
    });
    out
}

/// Quantize a dense matrix straight to packed form on the given
/// kernel path — bit-identical to [`PackedTensor::quantize_pack`]
/// (codes *and* scales): the lane-wise max reduction feeds the exact
/// same exponent derivation ([`shared_exponent_from_max`]), and the
/// INT8 vector quantizer reproduces the scalar encode rounding.
pub fn quantize_pack(path: KernelPath, m: &Mat, format: ElementFormat) -> PackedTensor {
    if path == KernelPath::Swar || !SIMD_FORMATS.contains(&format) {
        return PackedTensor::quantize_pack(m, format);
    }
    let brows = m.rows.div_ceil(SQ);
    let bcols = m.cols.div_ceil(SQ);
    let w = format.bits();
    let tiles = par::par_map(brows * bcols, PAR_MIN_BLOCKS, |t| {
        let (br, bc) = (t / bcols, t % bcols);
        let mut vals = [0.0f32; SQ_ELEMS];
        for i in 0..SQ {
            for j in 0..SQ {
                let (r, c) = (br * SQ + i, bc * SQ + j);
                if r < m.rows && c < m.cols {
                    vals[i * SQ + j] = m.at(r, c);
                }
            }
        }
        let se = shared_exponent_from_max(max_abs(path, &vals), format);
        let mut lanes = [0u64; SQ];
        match format {
            ElementFormat::Int8 => quantize_tile_int8(path, &vals, se, &mut lanes),
            _ => {
                // E2M1: vectorized max reduction above, scalar encode
                // for the 4-bit pack (16 codes — encode is a handful
                // of compares, not the bottleneck)
                let inv = exp2i(-se);
                for (i, lane) in lanes.iter_mut().enumerate() {
                    for j in 0..SQ {
                        let code = format.encode(vals[i * SQ + j] as f64 * inv);
                        *lane |= (code as u64) << (j as u32 * w);
                    }
                }
            }
        }
        (se as i8, lanes)
    });
    let mut scales = Vec::with_capacity(tiles.len());
    let mut lanes = Vec::with_capacity(tiles.len() * SQ);
    for (se, tl) in tiles {
        scales.push(se);
        lanes.extend_from_slice(&tl);
    }
    PackedTensor { rows: m.rows, cols: m.cols, format, brows, bcols, scales, lanes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::packed::dot8_i8_scalar;
    use crate::util::rng::Pcg64;

    /// Every path whose guard can actually fire on this machine.
    fn live_paths() -> Vec<KernelPath> {
        let f = detect::features();
        KernelPath::ALL.iter().copied().filter(|p| p.available(f)).collect()
    }

    fn rand_dec(rng: &mut Pcg64) -> [i8; SQ_ELEMS] {
        let mut d = [0i8; SQ_ELEMS];
        for v in d.iter_mut() {
            *v = (rng.next_u64() as u8 as i8).clamp(-127, 127);
        }
        d
    }

    #[test]
    fn swar_tile_dot_matches_lane_oracle() {
        // the twin must agree with the packed module's scalar lane dot
        let mut rng = Pcg64::new(0x51D0);
        for _ in 0..200 {
            let a = rand_dec(&mut rng);
            let b = rand_dec(&mut rng);
            let mut dots = [0i32; SQ_ELEMS];
            tile_dots_i8_swar(&a, &b, &mut dots);
            for i in 0..SQ {
                let mut al = [0i8; SQ];
                al.copy_from_slice(&a[i * SQ..(i + 1) * SQ]);
                for j in 0..SQ {
                    let mut bl = [0i8; SQ];
                    for (k, slot) in bl.iter_mut().enumerate() {
                        *slot = b[k * SQ + j];
                    }
                    let la = u64::from_le_bytes(al.map(|v| v as u8));
                    let lb = u64::from_le_bytes(bl.map(|v| v as u8));
                    assert_eq!(dots[i * SQ + j], dot8_i8_scalar(la, lb), "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn every_live_path_tile_dot_matches_swar() {
        let mut rng = Pcg64::new(0xD1D0);
        for path in live_paths() {
            for _ in 0..100 {
                let a = rand_dec(&mut rng);
                let b = rand_dec(&mut rng);
                let mut want = [0i32; SQ_ELEMS];
                let mut got = [0i32; SQ_ELEMS];
                tile_dots_i8_swar(&a, &b, &mut want);
                tile_dots_i8(path, &a, &b, &mut got);
                assert_eq!(got, want, "{path:?}");
            }
        }
    }

    #[test]
    fn every_live_path_e2m1_decode_matches_swar() {
        let mut rng = Pcg64::new(0xE2E1);
        for path in live_paths() {
            for _ in 0..100 {
                let mut lanes = [0u64; SQ];
                for l in lanes.iter_mut() {
                    *l = rng.next_u64() & 0xffff_ffff;
                }
                let mut want = [0i8; SQ_ELEMS];
                let mut got = [0i8; SQ_ELEMS];
                decode_tile_e2m1_swar(&lanes, &mut want);
                decode_tile_e2m1(path, &lanes, &mut got);
                assert_eq!(got, want, "{path:?}");
            }
        }
    }

    #[test]
    fn every_live_path_transpose_matches_swar() {
        let mut rng = Pcg64::new(0x7870);
        for path in live_paths() {
            for _ in 0..100 {
                let x = rand_dec(&mut rng);
                let mut want = [0i8; SQ_ELEMS];
                let mut got = [0i8; SQ_ELEMS];
                transpose8x8_i8_swar(&x, &mut want);
                transpose8x8_i8(path, &x, &mut got);
                assert_eq!(got, want, "{path:?}");
            }
        }
    }

    #[test]
    fn every_live_path_max_abs_matches_swar() {
        let mut rng = Pcg64::new(0x3A8);
        for path in live_paths() {
            for round in 0..200 {
                let mut vals = [0.0f32; SQ_ELEMS];
                for v in vals.iter_mut() {
                    *v = rng.wide_f32();
                }
                // seed pathological entries: NaN, ±inf, -0.0
                if round % 4 == 0 {
                    vals[round % SQ_ELEMS] = f32::NAN;
                    vals[(round + 7) % SQ_ELEMS] = f32::NEG_INFINITY;
                    vals[(round + 13) % SQ_ELEMS] = -0.0;
                }
                let want = max_abs_swar(&vals);
                let got = max_abs(path, &vals);
                assert_eq!(got.to_bits(), want.to_bits(), "{path:?} round {round}");
            }
        }
    }

    #[test]
    fn every_live_path_int8_quantize_matches_swar() {
        let mut rng = Pcg64::new(0x0148);
        for path in live_paths() {
            for round in 0..200 {
                let mut vals = [0.0f32; SQ_ELEMS];
                for v in vals.iter_mut() {
                    *v = rng.wide_f32();
                }
                if round % 5 == 0 {
                    vals[round % SQ_ELEMS] = f32::NAN;
                    vals[(round + 3) % SQ_ELEMS] = -0.0;
                    vals[(round + 9) % SQ_ELEMS] = f32::INFINITY;
                }
                let se = shared_exponent_from_max(max_abs_swar(&vals), ElementFormat::Int8);
                let mut want = [0u64; SQ];
                let mut got = [0u64; SQ];
                quantize_tile_int8_swar(&vals, se, &mut want);
                quantize_tile_int8(path, &vals, se, &mut got);
                assert_eq!(got, want, "{path:?} round {round} se {se}");
            }
        }
    }

    #[test]
    fn swar_quantize_twin_matches_quantize_pack() {
        // the twin is defined as "the same loop quantize_pack runs";
        // pin that on a full 8x8 block
        let mut rng = Pcg64::new(0x9A57);
        for _ in 0..50 {
            let m = Mat::from_fn(SQ, SQ, |_, _| rng.wide_f32());
            let p = PackedTensor::quantize_pack(&m, ElementFormat::Int8);
            let mut vals = [0.0f32; SQ_ELEMS];
            for i in 0..SQ {
                for j in 0..SQ {
                    vals[i * SQ + j] = m.at(i, j);
                }
            }
            let se = shared_exponent_from_max(max_abs_swar(&vals), ElementFormat::Int8);
            assert_eq!(se, p.scale_exp(0, 0));
            let mut lanes = [0u64; SQ];
            quantize_tile_int8_swar(&vals, se, &mut lanes);
            assert_eq!(&lanes[..], p.tile(0, 0));
        }
    }

    #[test]
    fn driver_gemm_matches_packed_on_live_paths() {
        let bits = |m: &Mat| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        let mut rng = Pcg64::new(0x6E33);
        for fmt in SIMD_FORMATS {
            for (m, k, n) in [(8, 8, 8), (16, 24, 16), (13, 9, 17)] {
                let am = Mat::from_fn(m, k, |_, _| rng.wide_f32().clamp(-1e6, 1e6));
                let bm = Mat::from_fn(k, n, |_, _| rng.wide_f32().clamp(-1e6, 1e6));
                let pa = PackedTensor::quantize_pack(&am, fmt);
                let pb = PackedTensor::quantize_pack(&bm, fmt);
                let pbt = PackedTensor::quantize_pack(&bm.transpose(), fmt);
                let want = packed_gemm(&pa, &pb);
                let want_nt = packed_gemm_nt(&pa, &pbt);
                for path in live_paths() {
                    let got = gemm(path, &pa, &pb);
                    assert_eq!(bits(&got), bits(&want), "{fmt:?} {path:?} gemm");
                    let got_nt = gemm_nt(path, &pa, &pbt);
                    assert_eq!(bits(&got_nt), bits(&want_nt), "{fmt:?} {path:?} nt");
                }
            }
        }
    }

    #[test]
    fn driver_quantize_pack_matches_scalar_on_live_paths() {
        let mut rng = Pcg64::new(0x9B17);
        for fmt in SIMD_FORMATS {
            for (r, c) in [(8, 8), (13, 21), (64, 64)] {
                let m = Mat::from_fn(r, c, |_, _| rng.wide_f32());
                let want = PackedTensor::quantize_pack(&m, fmt);
                for path in live_paths() {
                    let got = quantize_pack(path, &m, fmt);
                    assert_eq!(got, want, "{fmt:?} {path:?} {r}x{c}");
                }
            }
        }
    }
}
