//! One-time runtime CPU-feature detection for the SIMD kernel paths.
//!
//! Detection runs once per process (cached in a `OnceLock`) via
//! `is_x86_feature_detected!` / `is_aarch64_feature_detected!`; every
//! dispatch site reads the cached snapshot. Under miri the snapshot is
//! all-false, so the interpreter only ever sees the portable SWAR twins
//! (`std::arch` intrinsics are outside its supported surface).

#![forbid(unsafe_code)]

use std::sync::OnceLock;

/// The CPU features the kernel registry dispatches on. Constructed by
/// [`features`] for the running CPU, or literally by tests that need to
/// model a CPU without a feature (forced-fallback coverage).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CpuFeatures {
    /// x86-64 SSE4.1 (implies the SSSE3 byte shuffles the LUT dots use).
    pub sse41: bool,
    /// x86-64 AVX2 (256-bit integer lanes).
    pub avx2: bool,
    /// AArch64 Advanced SIMD.
    pub neon: bool,
}

impl CpuFeatures {
    /// A snapshot with nothing available — resolves every kernel to SWAR.
    pub const NONE: CpuFeatures = CpuFeatures { sse41: false, avx2: false, neon: false };

    /// Human-readable feature list ("avx2,sse4.1" / "neon" / "none") —
    /// the string stamped into `BENCH_*.json` provenance.
    pub fn describe(&self) -> String {
        let mut parts = Vec::new();
        if self.avx2 {
            parts.push("avx2");
        }
        if self.sse41 {
            parts.push("sse4.1");
        }
        if self.neon {
            parts.push("neon");
        }
        if parts.is_empty() {
            "none".to_string()
        } else {
            parts.join(",")
        }
    }
}

static FEATURES: OnceLock<CpuFeatures> = OnceLock::new();

/// The running CPU's feature snapshot (detected once, then cached).
pub fn features() -> CpuFeatures {
    *FEATURES.get_or_init(detect_now)
}

#[cfg(all(target_arch = "x86_64", not(miri)))]
fn detect_now() -> CpuFeatures {
    CpuFeatures {
        sse41: std::arch::is_x86_feature_detected!("sse4.1"),
        avx2: std::arch::is_x86_feature_detected!("avx2"),
        neon: false,
    }
}

#[cfg(all(target_arch = "aarch64", not(miri)))]
fn detect_now() -> CpuFeatures {
    CpuFeatures {
        sse41: false,
        avx2: false,
        neon: std::arch::is_aarch64_feature_detected!("neon"),
    }
}

#[cfg(any(not(any(target_arch = "x86_64", target_arch = "aarch64")), miri))]
fn detect_now() -> CpuFeatures {
    CpuFeatures::NONE
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detection_is_stable_across_calls() {
        assert_eq!(features(), features());
    }

    #[test]
    fn describe_names_every_flag() {
        assert_eq!(CpuFeatures::NONE.describe(), "none");
        let all = CpuFeatures { sse41: true, avx2: true, neon: true };
        assert_eq!(all.describe(), "avx2,sse4.1,neon");
        let sse = CpuFeatures { sse41: true, ..CpuFeatures::NONE };
        assert_eq!(sse.describe(), "sse4.1");
    }

    #[test]
    fn x86_feature_implication_holds() {
        // AVX2 CPUs always have SSE4.1; a detection snapshot violating
        // that would mean the cache was populated inconsistently
        let f = features();
        if f.avx2 {
            assert!(f.sse41, "avx2 detected without sse4.1");
        }
    }
}
