//! AArch64 NEON kernels — the i8 tile dot and the E2M1 nibble-LUT
//! decode, mirroring the x86 legs. Max-abs, transpose, and the INT8
//! quantizer fall back to the SWAR twins on this architecture (the
//! GeMM hot loop is the dot; the others are O(n²) prep), which keeps
//! the bit-identity contract trivially: fewer legs, same oracle.
//!
//! Operand conventions are identical to [`super::x86`]: `a_dec`
//! row-major, `b_dec` k-major, `dots[i*8+j] = Σₖ a·b` exact in i32.

#![cfg(target_arch = "aarch64")]

use crate::mx::packed::e2m1_mant_lut16;
use crate::mx::tensor::{SQ, SQ_ELEMS};
use std::arch::aarch64::*;

/// NEON 8×8×8 i8 tile dot: widen the eight k-major `b` rows to i16
/// once, then per output row broadcast each `a` element and
/// multiply-accumulate into two i32 quad accumulators (`vmlal_s16`).
/// Products ≤ 127² fit i16 exactly; sums fit i32 — no saturation.
///
/// # Safety
/// Requires NEON. Callers must have confirmed `neon` in the runtime
/// feature snapshot (the dispatcher in `mx::simd` does).
// SAFETY: `unsafe fn` solely for `#[target_feature]`; all pointer
// accesses below stay inside the fixed-size argument arrays.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn tile_dots_i8_neon(
    a_dec: &[i8; SQ_ELEMS],
    b_dec: &[i8; SQ_ELEMS],
    dots: &mut [i32; SQ_ELEMS],
) {
    let mut bw = [vdupq_n_s16(0); SQ];
    for (k, slot) in bw.iter_mut().enumerate() {
        *slot = vmovl_s8(vld1_s8(b_dec.as_ptr().add(SQ * k)));
    }
    for i in 0..SQ {
        let mut acc_lo = vdupq_n_s32(0);
        let mut acc_hi = vdupq_n_s32(0);
        for (k, bk) in bw.iter().enumerate() {
            let av = vdup_n_s16(a_dec[SQ * i + k] as i16);
            acc_lo = vmlal_s16(acc_lo, vget_low_s16(*bk), av);
            acc_hi = vmlal_s16(acc_hi, vget_high_s16(*bk), av);
        }
        vst1q_s32(dots.as_mut_ptr().add(SQ * i), acc_lo);
        vst1q_s32(dots.as_mut_ptr().add(SQ * i + 4), acc_hi);
    }
}

/// NEON E2M1 tile decode: nibble split + `vqtbl1q_s8` 16-entry LUT
/// ([`e2m1_mant_lut16`]), two passes of four lanes. Output matches the
/// SWAR twin byte for byte.
///
/// # Safety
/// Requires NEON. Callers must have confirmed `neon` in the runtime
/// feature snapshot.
// SAFETY: `unsafe fn` solely for `#[target_feature]`; all pointer
// accesses below stay inside the fixed-size argument arrays.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn decode_tile_e2m1_neon(lanes: &[u64; SQ], out: &mut [i8; SQ_ELEMS]) {
    let lut = vld1q_s8(e2m1_mant_lut16().as_ptr());
    let mask = vdupq_n_u8(0x0f);
    for half in 0..2 {
        let l = 4 * half;
        // four lanes' low u32s = 16 packed-nibble bytes
        let mut buf = [0u8; 16];
        for (q, lane) in lanes[l..l + 4].iter().enumerate() {
            buf[4 * q..4 * q + 4].copy_from_slice(&(*lane as u32).to_le_bytes());
        }
        let x = vld1q_u8(buf.as_ptr());
        let lo = vandq_u8(x, mask);
        let hi = vandq_u8(vshrq_n_u8::<4>(x), mask);
        // interleave even/odd nibbles back into code order j = 0..8
        let idx01 = vzip1q_u8(lo, hi); // rows l, l+1
        let idx23 = vzip2q_u8(lo, hi); // rows l+2, l+3
        let op = out.as_mut_ptr().add(32 * half);
        vst1q_s8(op, vqtbl1q_s8(lut, idx01));
        vst1q_s8(op.add(16), vqtbl1q_s8(lut, idx23));
    }
}
