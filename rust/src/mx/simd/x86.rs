//! x86-64 `std::arch` kernels (AVX2 + SSE4.1 legs).
//!
//! Every `#[target_feature]` function here is dispatched through
//! [`crate::mx::simd`]'s guard arms, which check the one-time runtime
//! feature snapshot ([`crate::mx::simd::detect::features`]) immediately
//! before the `unsafe` call — the dispatch-safety argument DESIGN.md
//! §10 spells out. Each kernel has a SWAR twin in the parent module
//! and is bit-identical to it (asserted with `==` on bits by the unit
//! tests there and the forced-path matrix in `tests/simd.rs`); lint
//! rule L8 enforces the twin/naming/cfg contract mechanically.
//!
//! Operand conventions match the twins exactly:
//! * `a_dec` — left tile decoded row-major: `a_dec[i*8 + k] = A[i][k]`.
//! * `b_dec` — right tile decoded k-major: `b_dec[k*8 + j] = B[k][j]`.
//! * `dots[i*8 + j] = Σₖ a_dec[i*8+k] · b_dec[k*8+j]`, exact in i32
//!   (|values| ≤ 127, so |Σ| ≤ 8·127² < 2¹⁷ — no saturation anywhere).

#![cfg(target_arch = "x86_64")]

use crate::mx::element::exp2i;
use crate::mx::packed::e2m1_mant_lut16;
use crate::mx::tensor::{SQ, SQ_ELEMS};
use std::arch::x86_64::*;

// ----------------------------------------------------------- i8 tile dot

/// AVX2 8×8×8 i8 tile dot (see module doc for the operand contract).
///
/// The eight k-major `b` rows are widened once into four 256-bit i16
/// vectors, each interleaving two adjacent k-rows per 32-bit group;
/// `_mm256_madd_epi16` then computes `a[i][2kp]·B[2kp][j] +
/// a[i][2kp+1]·B[2kp+1][j]` for all eight `j` at once. No intermediate
/// saturates: products ≤ 127² fit i16·i16→i32 madd exactly.
///
/// # Safety
/// Requires AVX2. Callers must have confirmed `avx2` in the runtime
/// feature snapshot (the dispatcher in `mx::simd` does).
// SAFETY: `unsafe fn` solely for `#[target_feature]`; all pointer
// accesses below stay inside the fixed-size argument arrays.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn tile_dots_i8_avx2(
    a_dec: &[i8; SQ_ELEMS],
    b_dec: &[i8; SQ_ELEMS],
    dots: &mut [i32; SQ_ELEMS],
) {
    // widen b row pairs: bk16[kp] holds i16 lanes (2j) = B[2kp][j] and
    // (2j+1) = B[2kp+1][j] for j = 0..8
    let bp = b_dec.as_ptr();
    let mut bk16 = [_mm256_setzero_si256(); 4];
    for (kp, slot) in bk16.iter_mut().enumerate() {
        let r0 = _mm_loadl_epi64(bp.add(16 * kp) as *const __m128i);
        let r1 = _mm_loadl_epi64(bp.add(16 * kp + 8) as *const __m128i);
        let inter = _mm_unpacklo_epi8(r0, r1);
        *slot = _mm256_cvtepi8_epi16(inter);
    }
    for i in 0..SQ {
        let mut acc = _mm256_setzero_si256();
        for (kp, bk) in bk16.iter().enumerate() {
            let lo = a_dec[SQ * i + 2 * kp] as i16 as u16 as u32;
            let hi = a_dec[SQ * i + 2 * kp + 1] as i16 as u16 as u32;
            let av = _mm256_set1_epi32((lo | (hi << 16)) as i32);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(av, *bk));
        }
        _mm256_storeu_si256(dots.as_mut_ptr().add(SQ * i) as *mut __m256i, acc);
    }
}

/// SSE4.1 leg of [`tile_dots_i8_avx2`]: same pairing trick over two
/// 128-bit halves (columns 0..4 and 4..8).
///
/// # Safety
/// Requires SSE4.1 (`_mm_cvtepi8_epi16`). Callers must have confirmed
/// `sse4.1` in the runtime feature snapshot.
// SAFETY: `unsafe fn` solely for `#[target_feature]`; all pointer
// accesses below stay inside the fixed-size argument arrays.
#[target_feature(enable = "sse4.1")]
pub(crate) unsafe fn tile_dots_i8_sse41(
    a_dec: &[i8; SQ_ELEMS],
    b_dec: &[i8; SQ_ELEMS],
    dots: &mut [i32; SQ_ELEMS],
) {
    let bp = b_dec.as_ptr();
    let mut blo = [_mm_setzero_si128(); 4];
    let mut bhi = [_mm_setzero_si128(); 4];
    for kp in 0..4 {
        let r0 = _mm_loadl_epi64(bp.add(16 * kp) as *const __m128i);
        let r1 = _mm_loadl_epi64(bp.add(16 * kp + 8) as *const __m128i);
        let inter = _mm_unpacklo_epi8(r0, r1);
        blo[kp] = _mm_cvtepi8_epi16(inter);
        bhi[kp] = _mm_cvtepi8_epi16(_mm_srli_si128::<8>(inter));
    }
    for i in 0..SQ {
        let mut acc_lo = _mm_setzero_si128();
        let mut acc_hi = _mm_setzero_si128();
        for kp in 0..4 {
            let lo = a_dec[SQ * i + 2 * kp] as i16 as u16 as u32;
            let hi = a_dec[SQ * i + 2 * kp + 1] as i16 as u16 as u32;
            let pair = _mm_set1_epi32((lo | (hi << 16)) as i32);
            acc_lo = _mm_add_epi32(acc_lo, _mm_madd_epi16(pair, blo[kp]));
            acc_hi = _mm_add_epi32(acc_hi, _mm_madd_epi16(pair, bhi[kp]));
        }
        _mm_storeu_si128(dots.as_mut_ptr().add(SQ * i) as *mut __m128i, acc_lo);
        _mm_storeu_si128(dots.as_mut_ptr().add(SQ * i + 4) as *mut __m128i, acc_hi);
    }
}

// ---------------------------------------------------------- E2M1 decode

/// AVX2 E2M1 tile decode: all 64 nibble codes of one packed tile →
/// integer mantissas (units of 2⁻¹, [`e2m1_mant_lut16`]) via one
/// 16-entry `_mm256_shuffle_epi8` LUT. Output is row-major i8, ready
/// for the i8 tile-dot kernels (products land in 2⁻² units — the same
/// unit the SWAR pair LUT uses, so sums agree exactly).
///
/// # Safety
/// Requires AVX2. Callers must have confirmed `avx2` in the runtime
/// feature snapshot.
// SAFETY: `unsafe fn` solely for `#[target_feature]`; all pointer
// accesses below stay inside the fixed-size argument arrays.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn decode_tile_e2m1_avx2(lanes: &[u64; SQ], out: &mut [i8; SQ_ELEMS]) {
    // each lane's 8 nibbles live in its low u32; gather all 8 lanes'
    // low words into one 256-bit register (lane l -> 32-bit group l)
    let x = _mm256_set_epi32(
        lanes[7] as u32 as i32,
        lanes[6] as u32 as i32,
        lanes[5] as u32 as i32,
        lanes[4] as u32 as i32,
        lanes[3] as u32 as i32,
        lanes[2] as u32 as i32,
        lanes[1] as u32 as i32,
        lanes[0] as u32 as i32,
    );
    let lut128 = _mm_loadu_si128(e2m1_mant_lut16().as_ptr() as *const __m128i);
    let lut256 = _mm256_broadcastsi128_si256(lut128);
    let mask = _mm256_set1_epi8(0x0f);
    let lo = _mm256_and_si256(x, mask);
    let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(x), mask);
    // interleave even/odd nibbles back into code order j = 0..8
    let idx01 = _mm256_unpacklo_epi8(lo, hi); // rows 0,1 | rows 4,5
    let idx23 = _mm256_unpackhi_epi8(lo, hi); // rows 2,3 | rows 6,7
    let d01 = _mm256_shuffle_epi8(lut256, idx01);
    let d23 = _mm256_shuffle_epi8(lut256, idx23);
    let op = out.as_mut_ptr();
    _mm_storeu_si128(op as *mut __m128i, _mm256_castsi256_si128(d01));
    _mm_storeu_si128(op.add(16) as *mut __m128i, _mm256_castsi256_si128(d23));
    _mm_storeu_si128(op.add(32) as *mut __m128i, _mm256_extracti128_si256::<1>(d01));
    _mm_storeu_si128(op.add(48) as *mut __m128i, _mm256_extracti128_si256::<1>(d23));
}

/// SSE4.1 leg of [`decode_tile_e2m1_avx2`]: two 128-bit passes of four
/// lanes each (`pshufb` is SSSE3, implied by SSE4.1).
///
/// # Safety
/// Requires SSE4.1. Callers must have confirmed `sse4.1` in the
/// runtime feature snapshot.
// SAFETY: `unsafe fn` solely for `#[target_feature]`; all pointer
// accesses below stay inside the fixed-size argument arrays.
#[target_feature(enable = "sse4.1")]
pub(crate) unsafe fn decode_tile_e2m1_sse41(lanes: &[u64; SQ], out: &mut [i8; SQ_ELEMS]) {
    let lut = _mm_loadu_si128(e2m1_mant_lut16().as_ptr() as *const __m128i);
    let mask = _mm_set1_epi8(0x0f);
    for half in 0..2 {
        let l = 4 * half;
        let x = _mm_set_epi32(
            lanes[l + 3] as u32 as i32,
            lanes[l + 2] as u32 as i32,
            lanes[l + 1] as u32 as i32,
            lanes[l] as u32 as i32,
        );
        let lo = _mm_and_si128(x, mask);
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(x), mask);
        let idx01 = _mm_unpacklo_epi8(lo, hi); // rows l, l+1
        let idx23 = _mm_unpackhi_epi8(lo, hi); // rows l+2, l+3
        let op = out.as_mut_ptr().add(32 * half);
        _mm_storeu_si128(op as *mut __m128i, _mm_shuffle_epi8(lut, idx01));
        _mm_storeu_si128(op.add(16) as *mut __m128i, _mm_shuffle_epi8(lut, idx23));
    }
}

// ------------------------------------------------------- 8×8 transpose

/// 8×8 i8 matrix transpose through the SSE2 unpack ladder (bytes →
/// 16-bit pairs → 32-bit quads → 64-bit columns). SSE2 is x86-64
/// baseline, so this is a **safe** function with an internal unsafe
/// block — no `#[target_feature]`, no runtime gate needed.
pub(crate) fn transpose8x8_i8_sse2(x: &[i8; SQ_ELEMS], out: &mut [i8; SQ_ELEMS]) {
    // SAFETY: SSE2 intrinsics are unconditionally available on x86-64
    // (baseline ISA); loads/stores stay inside the 64-byte arrays.
    unsafe {
        let p = x.as_ptr();
        let r01 = _mm_loadu_si128(p as *const __m128i);
        let r23 = _mm_loadu_si128(p.add(16) as *const __m128i);
        let r45 = _mm_loadu_si128(p.add(32) as *const __m128i);
        let r67 = _mm_loadu_si128(p.add(48) as *const __m128i);
        // interleave row pairs byte-wise: a0 = r0⊗r1, a1 = r2⊗r3, ...
        let a0 = _mm_unpacklo_epi8(r01, _mm_srli_si128::<8>(r01));
        let a1 = _mm_unpacklo_epi8(r23, _mm_srli_si128::<8>(r23));
        let a2 = _mm_unpacklo_epi8(r45, _mm_srli_si128::<8>(r45));
        let a3 = _mm_unpacklo_epi8(r67, _mm_srli_si128::<8>(r67));
        // 16-bit interleave: quads of rows
        let b0 = _mm_unpacklo_epi16(a0, a1);
        let b1 = _mm_unpackhi_epi16(a0, a1);
        let b2 = _mm_unpacklo_epi16(a2, a3);
        let b3 = _mm_unpackhi_epi16(a2, a3);
        // 32-bit interleave: full 8-byte columns, two per register
        let c0 = _mm_unpacklo_epi32(b0, b2); // cols 0,1
        let c1 = _mm_unpackhi_epi32(b0, b2); // cols 2,3
        let c2 = _mm_unpacklo_epi32(b1, b3); // cols 4,5
        let c3 = _mm_unpackhi_epi32(b1, b3); // cols 6,7
        let op = out.as_mut_ptr();
        _mm_storeu_si128(op as *mut __m128i, c0);
        _mm_storeu_si128(op.add(16) as *mut __m128i, c1);
        _mm_storeu_si128(op.add(32) as *mut __m128i, c2);
        _mm_storeu_si128(op.add(48) as *mut __m128i, c3);
    }
}

// ------------------------------------------------------------- max-abs

/// AVX2 max-|v| reduction over one gathered 64-element tile. `|v|`
/// is the **first** `maxps` operand: `maxps` returns its second
/// operand when the first is NaN, which reproduces the scalar
/// `fold(0.0, m.max(v.abs()))` NaN-skipping semantics bit for bit
/// (the accumulator is never NaN).
///
/// # Safety
/// Requires AVX2. Callers must have confirmed `avx2` in the runtime
/// feature snapshot.
// SAFETY: `unsafe fn` solely for `#[target_feature]`; all pointer
// accesses below stay inside the fixed-size argument array.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn max_abs_avx2(vals: &[f32; SQ_ELEMS]) -> f32 {
    let sign = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
    let mut acc = _mm256_setzero_ps();
    for chunk in 0..8 {
        let v = _mm256_loadu_ps(vals.as_ptr().add(8 * chunk));
        acc = _mm256_max_ps(_mm256_and_ps(v, sign), acc);
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    lanes.iter().fold(0.0f32, |m, &v| m.max(v))
}

/// SSE4.1 leg of [`max_abs_avx2`] (128-bit lanes).
///
/// # Safety
/// Requires SSE4.1 (kernel family gate; the ops are SSE baseline).
/// Callers must have confirmed `sse4.1` in the runtime snapshot.
// SAFETY: `unsafe fn` solely for `#[target_feature]`; all pointer
// accesses below stay inside the fixed-size argument array.
#[target_feature(enable = "sse4.1")]
pub(crate) unsafe fn max_abs_sse41(vals: &[f32; SQ_ELEMS]) -> f32 {
    let sign = _mm_castsi128_ps(_mm_set1_epi32(0x7fff_ffff));
    let mut acc = _mm_setzero_ps();
    for chunk in 0..16 {
        let v = _mm_loadu_ps(vals.as_ptr().add(4 * chunk));
        acc = _mm_max_ps(_mm_and_ps(v, sign), acc);
    }
    let mut lanes = [0.0f32; 4];
    _mm_storeu_ps(lanes.as_mut_ptr(), acc);
    lanes.iter().fold(0.0f32, |m, &v| m.max(v))
}

// ----------------------------------------------------- INT8 quantize

/// AVX2 INT8 tile quantizer: 64 gathered f32s → 8 packed u64 lanes,
/// bit-identical to the scalar `encode` loop. The scalar path computes
/// `rne(v·2⁻ˢᵉ·64).clamp(±127)` in f64; here the two power-of-two
/// factors fuse into one exact f64 multiplier (2^(6−se), |exponent| ≤
/// 133 — no over/underflow), `roundpd` supplies round-ties-even, and a
/// compare-ordered mask zeroes NaNs **before** the clamp (matching the
/// scalar `as i32` NaN→0 collapse).
///
/// # Safety
/// Requires AVX2. Callers must have confirmed `avx2` in the runtime
/// feature snapshot.
// SAFETY: `unsafe fn` solely for `#[target_feature]`; all pointer
// accesses below stay inside the fixed-size argument arrays.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn quantize_tile_int8_avx2(
    vals: &[f32; SQ_ELEMS],
    se: i32,
    lanes: &mut [u64; SQ],
) {
    let mul = _mm256_set1_pd(exp2i(6 - se));
    let lo_c = _mm256_set1_pd(-127.0);
    let hi_c = _mm256_set1_pd(127.0);
    for (i, lane) in lanes.iter_mut().enumerate() {
        let mut q8 = [_mm_setzero_si128(); 2];
        for (h, qs) in q8.iter_mut().enumerate() {
            let v = _mm_loadu_ps(vals.as_ptr().add(SQ * i + 4 * h));
            let mut x = _mm256_cvtps_pd(v);
            let ord = _mm256_cmp_pd::<_CMP_ORD_Q>(x, x);
            x = _mm256_and_pd(x, ord);
            x = _mm256_mul_pd(x, mul);
            x = _mm256_round_pd::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(x);
            x = _mm256_max_pd(x, lo_c);
            x = _mm256_min_pd(x, hi_c);
            *qs = _mm256_cvtpd_epi32(x);
        }
        // 8 i32 codes in [-127,127] -> 8 bytes, no saturation possible
        let q16 = _mm_packs_epi32(q8[0], q8[1]);
        let q = _mm_packs_epi16(q16, _mm_setzero_si128());
        *lane = _mm_cvtsi128_si64(q) as u64;
    }
}

/// SSE4.1 leg of [`quantize_tile_int8_avx2`]: two f32s at a time
/// through `cvtps_pd` (the 8-byte `loadl_epi64` keeps the final
/// row-chunk load inside the array).
///
/// # Safety
/// Requires SSE4.1 (`roundpd`). Callers must have confirmed `sse4.1`
/// in the runtime feature snapshot.
// SAFETY: `unsafe fn` solely for `#[target_feature]`; all pointer
// accesses below stay inside the fixed-size argument arrays.
#[target_feature(enable = "sse4.1")]
pub(crate) unsafe fn quantize_tile_int8_sse41(
    vals: &[f32; SQ_ELEMS],
    se: i32,
    lanes: &mut [u64; SQ],
) {
    let mul = _mm_set1_pd(exp2i(6 - se));
    let lo_c = _mm_set1_pd(-127.0);
    let hi_c = _mm_set1_pd(127.0);
    for (i, lane) in lanes.iter_mut().enumerate() {
        let mut qs = [_mm_setzero_si128(); 4];
        for (h, q) in qs.iter_mut().enumerate() {
            // exactly 8 bytes: a full f32 load at i=7,h=3 would run
            // off the end of the 256-byte array
            let v = _mm_castsi128_ps(_mm_loadl_epi64(
                vals.as_ptr().add(SQ * i + 2 * h) as *const __m128i
            ));
            let mut x = _mm_cvtps_pd(v);
            let ord = _mm_cmpord_pd(x, x);
            x = _mm_and_pd(x, ord);
            x = _mm_mul_pd(x, mul);
            x = _mm_round_pd::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(x);
            x = _mm_max_pd(x, lo_c);
            x = _mm_min_pd(x, hi_c);
            *q = _mm_cvtpd_epi32(x);
        }
        let p01 = _mm_unpacklo_epi64(qs[0], qs[1]);
        let p23 = _mm_unpacklo_epi64(qs[2], qs[3]);
        let q16 = _mm_packs_epi32(p01, p23);
        let q = _mm_packs_epi16(q16, _mm_setzero_si128());
        *lane = _mm_cvtsi128_si64(q) as u64;
    }
}
