//! Bit-exact element-format codecs for the six MX element types.
//!
//! Encoding follows OCP MX spec v1.0 semantics: round-to-nearest-even on
//! the mantissa grid, saturate to the format's largest magnitude, flush
//! magnitudes below half the smallest subnormal to (signed) zero. None of
//! the sub-FP8 formats carry Inf/NaN; E5M2's IEEE specials are excluded by
//! saturation (as in MX dot-product hardware, which never emits them).
//!
//! Codes are stored as the format's natural bit pattern in a `u8`:
//! sign-magnitude `s | e | m` for the FP formats, two's-complement for
//! INT8 (the OCP MXINT8 element: implied scale 2^-6, i.e. 1 sign bit,
//! 1 integer bit, 6 fraction bits).

#![forbid(unsafe_code)]

/// One of the six MX element formats from the paper's Table I.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElementFormat {
    /// MXINT8 element: 8-bit two's complement, implied scale 2^-6.
    Int8,
    /// MXFP8 E5M2: 1s + 5e + 2m, bias 15.
    E5M2,
    /// MXFP8 E4M3: 1s + 4e + 3m, bias 7.
    E4M3,
    /// MXFP6 E3M2: 1s + 3e + 2m, bias 3.
    E3M2,
    /// MXFP6 E2M3: 1s + 2e + 3m, bias 1.
    E2M3,
    /// MXFP4 E2M1: 1s + 2e + 1m, bias 1.
    E2M1,
}

impl ElementFormat {
    /// Total storage bits per element.
    pub const fn bits(&self) -> u32 {
        match self {
            ElementFormat::Int8 | ElementFormat::E5M2 | ElementFormat::E4M3 => 8,
            ElementFormat::E3M2 | ElementFormat::E2M3 => 6,
            ElementFormat::E2M1 => 4,
        }
    }

    /// Exponent field width (0 for INT8).
    pub const fn exp_bits(&self) -> u32 {
        match self {
            ElementFormat::Int8 => 0,
            ElementFormat::E5M2 => 5,
            ElementFormat::E4M3 => 4,
            ElementFormat::E3M2 => 3,
            ElementFormat::E2M3 | ElementFormat::E2M1 => 2,
        }
    }

    /// Mantissa (fraction) field width.
    pub const fn mant_bits(&self) -> u32 {
        match self {
            ElementFormat::Int8 => 6, // fraction bits of the 2^-6 fixed point
            ElementFormat::E5M2 => 2,
            ElementFormat::E4M3 => 3,
            ElementFormat::E3M2 => 2,
            ElementFormat::E2M3 => 3,
            ElementFormat::E2M1 => 1,
        }
    }

    /// IEEE-style exponent bias.
    pub const fn bias(&self) -> i32 {
        match self {
            ElementFormat::Int8 => 0,
            ElementFormat::E5M2 => 15,
            ElementFormat::E4M3 => 7,
            ElementFormat::E3M2 => 3,
            ElementFormat::E2M3 => 1,
            ElementFormat::E2M1 => 1,
        }
    }

    /// Exponent of the largest power of two representable (OCP `emax`).
    /// This is what divides the block max when deriving the shared scale.
    pub const fn emax(&self) -> i32 {
        match self {
            ElementFormat::Int8 => 0, // largest power of two in [-2,2) grid is 1
            ElementFormat::E5M2 => 15,
            ElementFormat::E4M3 => 8, // E4M3 reclaims the top exponent (no Inf)
            ElementFormat::E3M2 => 4,
            ElementFormat::E2M3 => 2,
            ElementFormat::E2M1 => 2,
        }
    }

    /// Smallest normal exponent (1 - bias).
    pub const fn emin(&self) -> i32 {
        1 - self.bias()
    }

    /// Largest representable magnitude (the saturation value).
    pub fn max_value(&self) -> f64 {
        match self {
            ElementFormat::Int8 => 127.0 / 64.0, // 1.984375
            // (2 - 2^-m) * 2^emax, except E4M3 which loses its top
            // mantissa code to NaN: max = 1.75 * 2^8 = 448.
            ElementFormat::E5M2 => (2.0 - 0.25) * (1u64 << 15) as f64, // 57344
            ElementFormat::E4M3 => 448.0,
            ElementFormat::E3M2 => (2.0 - 0.25) * 16.0, // 28
            ElementFormat::E2M3 => (2.0 - 0.125) * 4.0, // 7.5
            ElementFormat::E2M1 => (2.0 - 0.5) * 4.0,   // 6
        }
    }

    /// Smallest positive (subnormal) magnitude.
    pub fn min_subnormal(&self) -> f64 {
        match self {
            ElementFormat::Int8 => 1.0 / 64.0,
            _ => exp2i(self.emin() - self.mant_bits() as i32),
        }
    }

    /// Short lowercase name used in CLI flags and artifact filenames.
    pub fn name(&self) -> &'static str {
        match self {
            ElementFormat::Int8 => "int8",
            ElementFormat::E5M2 => "e5m2",
            ElementFormat::E4M3 => "e4m3",
            ElementFormat::E3M2 => "e3m2",
            ElementFormat::E2M3 => "e2m3",
            ElementFormat::E2M1 => "e2m1",
        }
    }

    /// Paper-style display name ("MXFP8 (E4M3)" etc.).
    pub fn display(&self) -> &'static str {
        match self {
            ElementFormat::Int8 => "MXINT8",
            ElementFormat::E5M2 => "MXFP8 (E5M2)",
            ElementFormat::E4M3 => "MXFP8 (E4M3)",
            ElementFormat::E3M2 => "MXFP6 (E3M2)",
            ElementFormat::E2M3 => "MXFP6 (E2M3)",
            ElementFormat::E2M1 => "MXFP4 (E2M1)",
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<ElementFormat> {
        match s.to_ascii_lowercase().as_str() {
            "int8" | "mxint8" => Some(ElementFormat::Int8),
            "e5m2" => Some(ElementFormat::E5M2),
            "e4m3" => Some(ElementFormat::E4M3),
            "e3m2" => Some(ElementFormat::E3M2),
            "e2m3" => Some(ElementFormat::E2M3),
            "e2m1" => Some(ElementFormat::E2M1),
            _ => None,
        }
    }

    /// The MAC operating mode this element format selects (paper §III-A).
    pub fn mac_mode(&self) -> crate::arith::Mode {
        use crate::arith::Mode;
        match self {
            ElementFormat::Int8 => Mode::Int8,
            ElementFormat::E5M2 | ElementFormat::E4M3 | ElementFormat::E3M2 | ElementFormat::E2M3 => Mode::Fp8Fp6,
            ElementFormat::E2M1 => Mode::Fp4,
        }
    }

    /// Encode a (already scale-divided) value into this format's bit code.
    ///
    /// Round-to-nearest-even, saturating. Returns the natural bit pattern.
    pub fn encode(&self, v: f64) -> u8 {
        match self {
            ElementFormat::Int8 => {
                // fixed-point grid of 1/64, two's complement, saturating at
                // +127/-128 ... the OCP spec saturates symmetric at ±127/64?
                // Hardware (and the paper's INT8 MAC) uses the full two's
                // complement range; we keep -128 representable on decode but
                // saturate encodes at ±127 (symmetric), matching common MX
                // quantizer implementations (e.g. microxcaling reference).
                let q = rne(v * 64.0);
                let q = q.clamp(-127.0, 127.0);
                (q as i32 as i8) as u8
            }
            _ => self.encode_fp(v),
        }
    }

    /// Decode a bit code into its exact real value (no shared scale).
    pub fn decode(&self, code: u8) -> f64 {
        match self {
            ElementFormat::Int8 => (code as i8) as f64 / 64.0,
            _ => self.decode_fp(code),
        }
    }

    fn encode_fp(&self, v: f64) -> u8 {
        let (eb, mb, bias) = (self.exp_bits(), self.mant_bits(), self.bias());
        let sign = if v.is_sign_negative() { 1u8 } else { 0u8 };
        let a = v.abs();
        if a.is_nan() {
            // never produced by the datapath; map to max magnitude
            return (sign << (eb + mb)) | self.max_code();
        }
        let max = self.max_value();
        if a >= max {
            // saturate (covers +/-inf too)
            return (sign << (eb + mb)) | self.max_code();
        }
        let emin = self.emin();
        // quantize onto the grid: for exponent e, step = 2^(e - mb)
        // subnormals use e = emin. §Audit: the binade is read from the
        // f64 exponent field, not log2() — libm rounding at binade
        // boundaries must never shift the grid (OCP MX v1.0 §6.3 derives
        // it as an exact bit-field operation, and the fast QAT path in
        // `mx::block` does the same, so the two stay bit-identical).
        let e_real = if a == 0.0 { emin } else { floor_log2(a) };
        let e = e_real.max(emin);
        let step = exp2i(e - mb as i32);
        let q = rne(a / step); // integer number of steps
        let (mut exp_field, mut mant_field): (u32, u32);
        let m_ones = (1u64 << mb) as f64;
        if q >= 2.0 * m_ones {
            // rounded up across the binade: mantissa overflow -> e+1, m=0
            let e2 = e + 1;
            if e2 > self.emax() {
                return (sign << (eb + mb)) | self.max_code();
            }
            exp_field = (e2 + bias) as u32;
            mant_field = 0;
        } else if q >= m_ones {
            // normal: implicit leading one
            exp_field = (e + bias) as u32;
            mant_field = (q - m_ones) as u32;
        } else {
            // subnormal (only reachable when e == emin)
            exp_field = 0;
            mant_field = q as u32;
        }
        // E4M3: code s.1111.111 is NaN; saturation above already avoided
        // emitting it because max_value() == decode of s.1111.110.
        if *self == ElementFormat::E4M3 && exp_field == 0xf && mant_field == 0x7 {
            exp_field = 0xf;
            mant_field = 0x6;
        }
        (sign << (eb + mb)) | ((exp_field as u8) << mb) | (mant_field as u8)
    }

    fn decode_fp(&self, code: u8) -> f64 {
        let (eb, mb, bias) = (self.exp_bits(), self.mant_bits(), self.bias());
        let total = 1 + eb + mb;
        let code = code & ((1u16 << total) - 1) as u8;
        let sign = if (code >> (eb + mb)) & 1 == 1 { -1.0 } else { 1.0 };
        if self.is_special(code) {
            // E5M2 Inf/NaN (never produced by the saturating datapath)
            return if *self == ElementFormat::E5M2 && (code & 0x03) == 0 {
                sign * f64::INFINITY
            } else {
                f64::NAN
            };
        }
        let exp_field = ((code >> mb) & ((1 << eb) - 1) as u8) as i32;
        let mant_field = (code & ((1 << mb) - 1) as u8) as f64;
        let m_ones = (1u64 << mb) as f64;
        if exp_field == 0 {
            // subnormal
            sign * mant_field / m_ones * exp2i(self.emin())
        } else {
            sign * (1.0 + mant_field / m_ones) * exp2i(exp_field - bias)
        }
    }

    /// Bit code (without sign) of the maximum magnitude.
    fn max_code(&self) -> u8 {
        match self {
            ElementFormat::Int8 => 127,
            ElementFormat::E4M3 => 0x7e, // 1111.110 (1111.111 is NaN)
            ElementFormat::E5M2 => 0x7b, // 11110.11 (11111.xx are Inf/NaN)
            _ => {
                // E3M2 / E2M3 / E2M1 have no specials: all-ones is max
                let (eb, mb) = (self.exp_bits(), self.mant_bits());
                let e = ((1u8 << eb) - 1) << mb;
                let m = (1u8 << mb) - 1;
                e | m
            }
        }
    }

    /// True if `code` is an IEEE special (E5M2 Inf/NaN, E4M3 NaN) that
    /// the MX datapath never produces (saturating arithmetic).
    pub fn is_special(&self, code: u8) -> bool {
        match self {
            ElementFormat::E5M2 => (code & 0x7c) == 0x7c,
            ElementFormat::E4M3 => (code & 0x7f) == 0x7f,
            _ => false,
        }
    }

    /// Number of distinct codes (for exhaustive tests).
    pub fn code_count(&self) -> usize {
        1usize << self.bits()
    }

    /// Fake-quantize: decode(encode(v)) — the QAT primitive.
    pub fn fake_quant(&self, v: f64) -> f64 {
        self.decode(self.encode(v))
    }

    /// Decompose an FP code into (sign, unbiased exponent, mantissa with
    /// implicit bit) — the representation the MAC datapath consumes.
    /// For subnormals the implicit bit is 0 and the exponent is emin.
    /// INT8 is not an FP format; panics.
    pub fn fp_parts(&self, code: u8) -> (i32, i32, u32) {
        assert!(*self != ElementFormat::Int8, "fp_parts on INT8");
        let (eb, mb) = (self.exp_bits(), self.mant_bits());
        let sign = if (code >> (eb + mb)) & 1 == 1 { -1 } else { 1 };
        let exp_field = ((code >> mb) & ((1 << eb) - 1) as u8) as i32;
        let mant_field = (code & ((1 << mb) - 1) as u8) as u32;
        if exp_field == 0 {
            (sign, self.emin(), mant_field) // subnormal: no implicit bit
        } else {
            (sign, exp_field - self.bias(), mant_field | (1 << mb))
        }
    }
}

/// 2^e as f64, exact for the exponent ranges involved here.
pub fn exp2i(e: i32) -> f64 {
    (e as f64).exp2()
}

/// Exact `floor(log2(x))` for positive finite `x`, read straight from
/// the f64 exponent field (correct for f64 subnormals too). This is the
/// shared-exponent primitive of the whole crate: the element encoders,
/// the block quantizer, and the fast QAT path all derive their binade
/// through it, so no libm rounding discrepancy can split them.
#[inline]
pub fn floor_log2(x: f64) -> i32 {
    debug_assert!(x > 0.0 && x.is_finite());
    let bits = x.to_bits();
    let exp = ((bits >> 52) & 0x7ff) as i32;
    if exp == 0 {
        // f64 subnormal: locate the mantissa's top set bit
        -1075 + (64 - (bits & 0xf_ffff_ffff_ffff).leading_zeros() as i32)
    } else {
        exp - 1023
    }
}

/// Round half to even on an f64 that is an exact multiple count.
pub fn rne(x: f64) -> f64 {
    let f = x.floor();
    let d = x - f;
    if d > 0.5 {
        f + 1.0
    } else if d < 0.5 {
        f
    } else if (f as i64) % 2 == 0 {
        f
    } else {
        f + 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::forall;

    const FP_FORMATS: [ElementFormat; 5] = [
        ElementFormat::E5M2,
        ElementFormat::E4M3,
        ElementFormat::E3M2,
        ElementFormat::E2M3,
        ElementFormat::E2M1,
    ];

    /// Exhaustive-search encoder used as the oracle: nearest representable
    /// value, ties to even mantissa code.
    fn oracle_encode(fmt: ElementFormat, v: f64) -> f64 {
        let mut best = f64::INFINITY;
        let mut best_v = 0.0f64;
        for code in 0..fmt.code_count() as u16 {
            let code = code as u8;
            if fmt.is_special(code) {
                continue; // Inf/NaN code
            }
            let x = fmt.decode(code);
            let d = (x - v).abs();
            // tie-break toward even mantissa code (RNE)
            let better = d < best || (d == best && (code & 1) == 0);
            if better {
                best = d;
                best_v = x;
            }
        }
        best_v
    }

    #[test]
    fn table1_static_properties() {
        // Matches the paper's Table I.
        assert_eq!(ElementFormat::Int8.bits(), 8);
        assert_eq!(ElementFormat::E5M2.bits(), 8);
        assert_eq!(ElementFormat::E4M3.bits(), 8);
        assert_eq!(ElementFormat::E3M2.bits(), 6);
        assert_eq!(ElementFormat::E2M3.bits(), 6);
        assert_eq!(ElementFormat::E2M1.bits(), 4);
        assert_eq!(ElementFormat::E5M2.max_value(), 57344.0);
        assert_eq!(ElementFormat::E4M3.max_value(), 448.0);
        assert_eq!(ElementFormat::E3M2.max_value(), 28.0);
        assert_eq!(ElementFormat::E2M3.max_value(), 7.5);
        assert_eq!(ElementFormat::E2M1.max_value(), 6.0);
    }

    #[test]
    fn decode_known_e2m1_codes() {
        // E2M1 values: 0, 0.5, 1, 1.5, 2, 3, 4, 6 (positive half)
        let f = ElementFormat::E2M1;
        let vals: Vec<f64> = (0u8..8).map(|c| f.decode(c)).collect();
        assert_eq!(vals, vec![0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]);
        assert_eq!(f.decode(0b1001), -0.5);
    }

    #[test]
    fn decode_known_e4m3_codes() {
        let f = ElementFormat::E4M3;
        assert_eq!(f.decode(0x00), 0.0);
        assert_eq!(f.decode(0x01), exp2i(-9)); // smallest subnormal 2^-9
        assert_eq!(f.decode(0x08), exp2i(-6)); // smallest normal 2^-6
        assert_eq!(f.decode(0x7e), 448.0); // max
        assert_eq!(f.decode(0x38), 1.0);
    }

    #[test]
    fn decode_known_e5m2_codes() {
        let f = ElementFormat::E5M2;
        assert_eq!(f.decode(0x3c), 1.0);
        assert_eq!(f.decode(0x7b), 57344.0); // 1.75 * 2^15
        assert_eq!(f.decode(0x01), exp2i(-16)); // 2^-14 * 0.25
    }

    #[test]
    fn int8_codec_roundtrip_exact() {
        let f = ElementFormat::Int8;
        for code in 0..=255u8 {
            let v = f.decode(code);
            if (code as i8) == -128 {
                continue; // encoder saturates symmetric, decode-only code
            }
            assert_eq!(f.encode(v), code, "code {code} value {v}");
        }
    }

    #[test]
    fn fp_codec_roundtrip_exact_all_formats() {
        for fmt in FP_FORMATS {
            for code in 0..fmt.code_count() as u16 {
                let code = code as u8;
                if fmt.is_special(code) {
                    continue; // Inf/NaN
                }
                let v = fmt.decode(code);
                let re = fmt.encode(v);
                // -0.0 encodes to sign bit set; compare decoded values
                assert_eq!(
                    fmt.decode(re),
                    v,
                    "{fmt:?} code {code:#x} -> {v} -> {re:#x}"
                );
            }
        }
    }

    #[test]
    fn encode_matches_exhaustive_oracle() {
        for fmt in FP_FORMATS {
            forall(
                0xE1 ^ fmt.bits() as u64,
                2000,
                |r| {
                    // span the format's full range including boundaries
                    let m = fmt.max_value();
                    match r.below(4) {
                        0 => r.range_f64(-2.0 * m, 2.0 * m),
                        1 => r.range_f64(-1.0, 1.0) * fmt.min_subnormal() * 4.0,
                        2 => {
                            // exact midpoints between representables
                            let c = r.below(fmt.code_count() as u64 / 2) as u8;
                            let c2 = c.wrapping_add(1);
                            if fmt.is_special(c) || fmt.is_special(c2) {
                                1.0
                            } else {
                                let a = fmt.decode(c);
                                let b = fmt.decode(c2);
                                if b > a {
                                    (a + b) / 2.0
                                } else {
                                    a
                                }
                            }
                        }
                        _ => r.wide_f32() as f64,
                    }
                },
                |&v| {
                    let got = fmt.decode(fmt.encode(v));
                    let want = oracle_encode(fmt, v);
                    if (got - want).abs() > 0.0 && got.abs() != want.abs() {
                        return Err(format!("{fmt:?}: encode({v}) = {got}, oracle {want}"));
                    }
                    // distance must be minimal even if tie-break differs
                    if (got - v).abs() > (want - v).abs() + 1e-300 {
                        return Err(format!("{fmt:?}: encode({v}) = {got} not nearest ({want})"));
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn saturation_at_max() {
        for fmt in FP_FORMATS {
            let m = fmt.max_value();
            assert_eq!(fmt.fake_quant(m * 8.0), m);
            assert_eq!(fmt.fake_quant(-m * 8.0), -m);
            assert_eq!(fmt.fake_quant(f64::INFINITY), m);
        }
        assert_eq!(ElementFormat::Int8.fake_quant(5.0), 127.0 / 64.0);
    }

    #[test]
    fn tiny_values_flush_to_zero() {
        for fmt in FP_FORMATS {
            let eps = fmt.min_subnormal();
            assert_eq!(fmt.fake_quant(eps * 0.49), 0.0, "{fmt:?}");
            assert_eq!(fmt.fake_quant(eps), eps, "{fmt:?}");
        }
    }

    #[test]
    fn rne_ties_to_even() {
        assert_eq!(rne(0.5), 0.0);
        assert_eq!(rne(1.5), 2.0);
        assert_eq!(rne(2.5), 2.0);
        assert_eq!(rne(-0.5), 0.0);
        assert_eq!(rne(2.4), 2.0);
        assert_eq!(rne(2.6), 3.0);
    }

    #[test]
    fn fp_parts_reconstruct_value() {
        for fmt in FP_FORMATS {
            for code in 0..fmt.code_count() as u16 {
                let code = code as u8;
                if fmt.is_special(code) {
                    continue;
                }
                let (s, e, m) = fmt.fp_parts(code);
                let v = s as f64 * m as f64 * exp2i(e - fmt.mant_bits() as i32);
                assert_eq!(v, fmt.decode(code), "{fmt:?} code {code:#x}");
            }
        }
    }

    #[test]
    fn floor_log2_exact_at_binade_boundaries() {
        for e in -300..300 {
            let x = exp2i(e);
            assert_eq!(floor_log2(x), e, "2^{e}");
            assert_eq!(floor_log2(x * 1.5), e, "1.5 * 2^{e}");
            // just below a power of two belongs to the lower binade
            let below = f64::from_bits(x.to_bits() - 1);
            assert_eq!(floor_log2(below), e - 1, "pred(2^{e})");
        }
        // f64 subnormals
        assert_eq!(floor_log2(f64::MIN_POSITIVE), -1022);
        assert_eq!(floor_log2(f64::MIN_POSITIVE / 2.0), -1023);
        assert_eq!(floor_log2(f64::from_bits(1)), -1074);
    }

    #[test]
    fn encode_exact_on_binade_boundaries() {
        // values exactly on a representable power of two must round-trip
        // exactly in every format (the audit's regression surface)
        for fmt in FP_FORMATS {
            for e in fmt.emin()..=fmt.emax() {
                let v = exp2i(e);
                assert_eq!(fmt.fake_quant(v), v, "{fmt:?} 2^{e}");
                assert_eq!(fmt.fake_quant(-v), -v, "{fmt:?} -2^{e}");
            }
        }
    }

    #[test]
    fn e4m3_never_encodes_nan_pattern() {
        // values right at/above max must hit 0x7e not 0x7f
        let f = ElementFormat::E4M3;
        for v in [447.9, 448.0, 449.0, 1e9] {
            assert_ne!(f.encode(v) & 0x7f, 0x7f);
        }
    }
}
