//! Shared-exponent block quantization (OCP MX spec §5.2 semantics).
//!
//! A block is a group of elements sharing one E8M0 power-of-two scale `X`.
//! Per the spec (and the paper's §II-A): `X = 2^(floor(log2(max_abs)) -
//! emax_elem)` — the largest power of two in the block divided by the
//! largest power of two representable in the element format — clamped to
//! E8M0's range. Elements are then encoded as `encode(v / X)`.

#![forbid(unsafe_code)]

use crate::mx::element::{exp2i, floor_log2, ElementFormat};

/// E8M0 scale exponent range. (Code 0xFF is NaN in the spec; we clamp.)
pub const SCALE_EMIN: i32 = -127;
pub const SCALE_EMAX: i32 = 127;

/// A quantized block: one shared scale exponent + per-element codes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScaledBlock {
    /// Power-of-two scale: actual scale is 2^scale_exp.
    pub scale_exp: i32,
    /// Element format of `codes`.
    pub format: ElementFormat,
    /// Natural bit patterns, one per element.
    pub codes: Vec<u8>,
}

impl ScaledBlock {
    /// Scale as a real number.
    pub fn scale(&self) -> f64 {
        exp2i(self.scale_exp)
    }

    /// Decode element `i` to its real value.
    pub fn decode(&self, i: usize) -> f64 {
        self.format.decode(self.codes[i]) * self.scale()
    }

    /// Decode all elements.
    pub fn dequantize(&self) -> Vec<f64> {
        (0..self.codes.len()).map(|i| self.decode(i)).collect()
    }

    /// Storage bits: 8 (shared exponent) + n * element bits.
    pub fn storage_bits(&self) -> usize {
        8 + self.codes.len() * self.format.bits() as usize
    }
}

/// Derive the shared scale exponent for a group of values.
///
/// OCP MX v1.0: `shared_exp = floor(log2(max_abs)) - emax_elem`, clamped
/// to E8M0 range; all-zero blocks take the minimum scale.
pub fn shared_exponent(values: &[f32], format: ElementFormat) -> i32 {
    let max_abs = values.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    shared_exponent_from_max(max_abs, format)
}

/// The exponent-derivation half of [`shared_exponent`], factored out so
/// the SIMD quantizers ([`crate::mx::simd`]) can reduce the block max
/// in vector lanes and still share the exact exponent logic (the fold
/// above and a lane-wise max produce the same non-NaN maximum, so the
/// two paths stay bit-identical).
pub fn shared_exponent_from_max(max_abs: f32, format: ElementFormat) -> i32 {
    if max_abs == 0.0 || !max_abs.is_finite() {
        return SCALE_EMIN;
    }
    // §Audit: exact exponent-field extraction (shared with the element
    // encoders and the fast path) — log2().floor() can misround at
    // binade boundaries and silently shift the whole block's scale.
    let e = floor_log2(max_abs as f64);
    (e - format.emax()).clamp(SCALE_EMIN, SCALE_EMAX)
}

/// Quantize a slice of values into one shared-exponent block.
pub fn quantize_block(values: &[f32], format: ElementFormat) -> ScaledBlock {
    let scale_exp = shared_exponent(values, format);
    let inv = exp2i(-scale_exp);
    let codes = values.iter().map(|&v| format.encode(v as f64 * inv)).collect();
    ScaledBlock { scale_exp, format, codes }
}

/// Fake-quantize a slice in place through one shared-exponent block
/// (the QAT primitive used by the golden trainer).
pub fn fake_quant_block(values: &mut [f32], format: ElementFormat) {
    let b = quantize_block(values, format);
    for (v, i) in values.iter_mut().zip(0..b.codes.len()) {
        *v = b.decode(i) as f32;
    }
}

/// Worst-case relative quantization step for a format (distance between
/// adjacent representables at the top of the range, relative to max) —
/// used by tests to bound round-trip error.
pub fn rel_step(format: ElementFormat) -> f64 {
    match format {
        ElementFormat::Int8 => 1.0 / 127.0,
        _ => exp2i(-(format.mant_bits() as i32)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::ALL_ELEMENT_FORMATS;
    use crate::util::rng::Pcg64;
    use crate::util::testing::forall;

    #[test]
    fn shared_exponent_matches_spec_examples() {
        // block max 1.0, E4M3 (emax 8): scale = 2^(0-8) = 2^-8
        assert_eq!(shared_exponent(&[1.0, 0.5], ElementFormat::E4M3), -8);
        // block max 448 exactly: floor(log2 448) = 8 -> scale 2^0
        assert_eq!(shared_exponent(&[448.0], ElementFormat::E4M3), 0);
        // INT8: emax 0 -> scale = floor(log2(max))
        assert_eq!(shared_exponent(&[3.9], ElementFormat::Int8), 1);
        // all zeros -> min scale
        assert_eq!(shared_exponent(&[0.0; 4], ElementFormat::E2M1), SCALE_EMIN);
    }

    #[test]
    fn quantize_exact_powers_of_two_roundtrip() {
        for fmt in ALL_ELEMENT_FORMATS {
            let vals = [1.0f32, 0.5, 0.25, -1.0];
            let b = quantize_block(&vals, fmt);
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(b.decode(i), v as f64, "{fmt:?} elem {i}");
            }
        }
    }

    #[test]
    fn block_max_never_saturates_catastrophically() {
        // The element holding the block max must round-trip within one
        // mantissa step — the scale derivation guarantees max/X <= 2*emax
        // power, possibly saturating by at most the top step.
        forall(
            0xB10C,
            512,
            |r| {
                let fmt = ALL_ELEMENT_FORMATS[r.below(6) as usize];
                let n = 32;
                let mut v = vec![0.0f32; n];
                for x in v.iter_mut() {
                    *x = r.wide_f32();
                }
                (fmt, v)
            },
            |(fmt, v)| {
                let b = quantize_block(v, *fmt);
                let max_idx = (0..v.len()).max_by(|&i, &j| v[i].abs().total_cmp(&v[j].abs())).unwrap();
                let orig = v[max_idx] as f64;
                let got = b.decode(max_idx);
                let tol = rel_step(*fmt) * orig.abs() * 1.01 + 1e-30;
                if (got - orig).abs() > tol {
                    return Err(format!("{fmt:?}: max elem {orig} -> {got}, tol {tol}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn relative_error_bounded_for_all_elements_int8() {
        // INT8 grid: absolute error <= scale * (1/64) / 2 per element
        forall(
            0xAB,
            256,
            |r| {
                let mut v = vec![0.0f32; 32];
                r.fill_normal(&mut v, 3.0);
                v
            },
            |v| {
                let b = quantize_block(v, ElementFormat::Int8);
                let half_step = b.scale() / 64.0 / 2.0;
                for (i, &orig) in v.iter().enumerate() {
                    let err = (b.decode(i) - orig as f64).abs();
                    // elements may saturate at +127 ... max elem defines scale,
                    // so err <= half step + saturation slack of one step
                    if err > half_step * 2.0 + 1e-30 {
                        return Err(format!("elem {i}: {orig} err {err} > {half_step}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn storage_bits_match_table1() {
        let v = vec![1.0f32; 32];
        assert_eq!(quantize_block(&v, ElementFormat::Int8).storage_bits(), 8 + 32 * 8);
        assert_eq!(quantize_block(&v, ElementFormat::E2M1).storage_bits(), 8 + 32 * 4);
    }

    #[test]
    fn fake_quant_idempotent() {
        for fmt in ALL_ELEMENT_FORMATS {
            let mut rng = Pcg64::new(fmt.bits() as u64);
            let mut v = vec![0.0f32; 64];
            rng.fill_normal(&mut v, 2.0);
            let mut once = v.clone();
            fake_quant_block(&mut once, fmt);
            let mut twice = once.clone();
            fake_quant_block(&mut twice, fmt);
            assert_eq!(once, twice, "{fmt:?} fake-quant not idempotent");
        }
    }

    #[test]
    fn int8_fast_path_flushes_negative_zero_like_the_codec() {
        use super::fake_quant_block_fast;
        // a negative value far below the block's quantization step rounds
        // to zero; two's-complement INT8 has no signed zero, so the codec
        // decodes +0.0 there and the fast path must match it bit-exactly
        // (round_ties_even alone would leave an IEEE -0.0 behind)
        let vals = [1000.0f32, -0.01];
        let mut fast = vals;
        fake_quant_block_fast(&mut fast, ElementFormat::Int8);
        assert_eq!(fast[1].to_bits(), 0.0f32.to_bits(), "-0.0 leaked");
        let b = quantize_block(&vals, ElementFormat::Int8);
        assert_eq!((b.decode(1) as f32).to_bits(), fast[1].to_bits());
    }

    #[test]
    fn zero_block_quantizes_to_zeros() {
        for fmt in ALL_ELEMENT_FORMATS {
            let b = quantize_block(&[0.0; 16], fmt);
            assert!(b.dequantize().iter().all(|&x| x == 0.0));
        }
    }
}

/// Fast fake-quantization of one block **in place** — the QAT hot path.
///
/// Numerically identical to `quantize_block` + `dequantize` (asserted by
/// tests) but touches no heap and replaces the generic `log2()` calls
/// with exponent-field extraction. Added in the §Perf pass: ~6x faster,
/// which is what makes the Fig. 2 sweep (7 schemes x 4 workloads x
/// hundreds of steps) practical.
pub fn fake_quant_block_fast(values: &mut [f32], format: ElementFormat) {
    let mut max_abs = 0.0f32;
    for v in values.iter() {
        max_abs = max_abs.max(v.abs());
    }
    if max_abs == 0.0 || !max_abs.is_finite() {
        for v in values.iter_mut() {
            *v = 0.0;
        }
        return;
    }
    // floor(log2(max_abs)) from the f64 exponent field (exact, and
    // correct for f32 subnormals after the widening cast)
    let e = floor_log2(max_abs as f64);
    let scale_exp = (e - format.emax()).clamp(SCALE_EMIN, SCALE_EMAX);
    let scale = exp2i(scale_exp);
    let inv = exp2i(-scale_exp);
    match format {
        ElementFormat::Int8 => {
            for v in values.iter_mut() {
                let q = (*v as f64 * inv * 64.0).round_ties_even().clamp(-127.0, 127.0);
                // `+ 0.0` flushes IEEE -0.0 (negative values rounding to
                // zero) to +0.0: the two's-complement INT8 codec has no
                // signed zero, so the codec path decodes +0.0 there and
                // this path must stay bit-identical to it.
                *v = ((q + 0.0) / 64.0 * scale) as f32;
            }
        }
        _ => {
            let mb = format.mant_bits() as i32;
            let emin = format.emin();
            let max = format.max_value();
            for v in values.iter_mut() {
                let x = *v as f64 * inv;
                let a = x.abs();
                if a == 0.0 {
                    *v = 0.0;
                    continue;
                }
                let e = floor_log2(a).max(emin);
                let step = exp2i(e - mb);
                let q = ((a / step).round_ties_even() * step).min(max);
                *v = (q.copysign(x) * scale) as f32;
            }
        }
    }
}
