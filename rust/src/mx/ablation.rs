//! Block-granularity ablation (the paper's §IV-A design choice).
//!
//! The paper selects 8x8 (64-element) squares "to balance granularity
//! and efficiency while maintaining compatibility with the MX standard"
//! (groups must be multiples of 32). This module quantizes through
//! arbitrary k x k squares so `mxscale repro ablation` can show the
//! tradeoff the authors navigated: smaller squares track local dynamic
//! range better (lower error) but pay more shared-exponent storage and
//! break MX-standard compatibility below 32 elements.

#![forbid(unsafe_code)]

use crate::mx::block::fake_quant_block_fast;
use crate::mx::element::ElementFormat;
use crate::util::mat::Mat;

/// Fake-quantize through k x k square blocks (k need not be 8).
pub fn fake_quant_square_k(m: &Mat, format: ElementFormat, k: usize) -> Mat {
    assert!(k > 0);
    let mut out = m.clone();
    let mut buf = vec![0.0f32; k * k];
    for br in 0..m.rows.div_ceil(k) {
        for bc in 0..m.cols.div_ceil(k) {
            for i in 0..k {
                for j in 0..k {
                    let (r, c) = (br * k + i, bc * k + j);
                    buf[i * k + j] = if r < m.rows && c < m.cols { m.at(r, c) } else { 0.0 };
                }
            }
            fake_quant_block_fast(&mut buf, format);
            for i in 0..k {
                for j in 0..k {
                    let (r, c) = (br * k + i, bc * k + j);
                    if r < m.rows && c < m.cols {
                        *out.at_mut(r, c) = buf[i * k + j];
                    }
                }
            }
        }
    }
    out
}

/// Storage bits/element for k x k squares (8-bit shared exponent each).
pub fn bits_per_element_k(format: ElementFormat, k: usize) -> f64 {
    format.bits() as f64 + 8.0 / (k * k) as f64
}

/// Whether a k x k square satisfies the MX standard's "groups are
/// multiples of 32 elements" constraint.
pub fn mx_standard_compatible(k: usize) -> bool {
    (k * k) % 32 == 0
}

/// One ablation row: block edge, bits/elem, MSE on the given data.
pub fn ablate(m: &Mat, format: ElementFormat, ks: &[usize]) -> Vec<(usize, f64, f64, bool)> {
    ks.iter()
        .map(|&k| {
            let q = fake_quant_square_k(m, format, k);
            (k, bits_per_element_k(format, k), q.mse(m), mx_standard_compatible(k))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::tensor::{fake_quant_mat_fast, Layout};
    use crate::util::rng::Pcg64;

    #[test]
    fn k8_matches_production_path() {
        let mut rng = Pcg64::new(1);
        let m = Mat::randn(32, 32, 1.0, &mut rng);
        let a = fake_quant_square_k(&m, ElementFormat::E4M3, 8);
        let b = fake_quant_mat_fast(&m, ElementFormat::E4M3, Layout::Square8x8);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn smaller_blocks_quantize_better_but_cost_more() {
        // data with per-4x4-tile scale variation
        let mut rng = Pcg64::new(2);
        let m = Mat::from_fn(32, 32, |r, c| {
            rng.normal_f32() * (((r / 4 + c / 4) % 5) as f32 * 2.0).exp2()
        });
        let rows = ablate(&m, ElementFormat::Int8, &[4, 8, 16]);
        // error grows with block size on locally-scaled data
        assert!(rows[0].2 <= rows[1].2 && rows[1].2 <= rows[2].2, "{rows:?}");
        // storage shrinks with block size
        assert!(rows[0].1 > rows[1].1 && rows[1].1 > rows[2].1);
    }

    #[test]
    fn standard_compatibility() {
        assert!(mx_standard_compatible(8)); // 64 = 2x32
        assert!(!mx_standard_compatible(4)); // 16 < 32
        assert!(mx_standard_compatible(16)); // 256 = 8x32
        assert!(!mx_standard_compatible(6)); // 36
    }
}
